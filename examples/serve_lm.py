"""Continuous-batching LM serving across replica groups (the paper's
multi-NCS pattern at LM scale) + tokens/s/W reporting.

Each replica keeps a fixed-slot decode batch saturated: a finished slot is
refilled by a chunked prefill of the next queued request (QUEUED -> PREFILL
-> DECODE -> DONE lifecycle in `repro.serving.scheduler`).  With more than
one replica, the `repro.serving.router.ReplicaRouter` dispatches requests
individually — to the replica already holding the prompt's longest prefix
(so cache-seeded prefill fires fleet-wide), falling back to block-aware
load (free KV blocks + queued prefill tokens, not raw request count) —
through `repro.core.offload`'s split-phase protocol, collected out of
order; an idle replica steals queued requests off a backlogged peer
(`--no-affinity` / `--no-steal` switch either mechanism off).  Admission is
SLO-aware: every third request here carries `priority=1` and a TTFT SLO,
so it is admitted ahead of the backlog (and, under KV-block pressure, may
preempt a lower-priority decode).  Stats include TTFT p50/p99, TPOT, slot
occupancy, SLO miss rate, and (paged) KV-pool peaks.

`--draft-model ARCH` turns on speculative decoding (paged KV only): a
drafter model proposes `--spec-k` tokens per slot per step and the target
scores all of them in one batched verify pass, committing the longest
prefix that matches its own greedy argmax — so greedy outputs stay
bit-identical while the target runs fewer steps.  Only greedy requests
speculate; the temperature-sampled ones here keep using vanilla decode in
the same batch.  Passing the target arch itself is self-speculation
(drafter shares the target's weights — no second model needed to demo).

`--host-blocks N` turns on the tiered KV cache: cold pool blocks (idle
shared prefixes, preemption victims' histories) spill to an N-block host
tier over the split-phase offload protocol and are restored — not
recomputed — when a later request (or the victim's resume) needs them;
`--kv-pool-blocks` shrinks the device pool so the tier actually engages.

`--replica-roles prefill,decode` disaggregates the fleet: prefill-role
replicas run chunked prefill at full budget (no decode steps contending)
and sample the first output token at handoff; the finished prompt's KV
blocks then migrate over the split-phase offload protocol to a
decode-role replica, which adopts them and decodes with zero prompt
recompute.  Greedy outputs stay bit-identical to a single mixed replica.

`--inject-faults PLAN` runs the same workload under deterministic chaos
(`site[:action[:after[:count]]]` specs or `seed=<int>`): a killed replica
is quarantined and its requests retried on survivors (`--max-retries`),
restarting from the bare prompt so greedy outputs are unchanged;
`--deadline-s` cancels any request that overstays with a typed
DeadlineExceeded and reclaims its KV blocks.

  PYTHONPATH=src python examples/serve_lm.py [--replicas 2] [--no-affinity]
      [--no-steal] [--draft-model qwen2.5-3b] [--spec-k 3] [--no-spec]
      [--host-blocks 32 --kv-pool-blocks 8]
      [--replica-roles prefill,decode]
      [--inject-faults replica.executor:raise:4 --max-retries 2]
      [--deadline-s 30]
"""
import argparse

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.router import ReplicaRouter
from repro.serving.sampler import greedy, temperature


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--no-affinity", action="store_true",
                    help="route by block-aware load alone (no fleet-wide "
                         "prefix-affinity dispatch)")
    ap.add_argument("--no-steal", action="store_true",
                    help="idle replicas no longer steal queued requests "
                         "from backlogged peers")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="speculative decoding drafter arch (same arch as "
                         "--arch = self-speculation); greedy requests "
                         "commit multiple tokens per target step, outputs "
                         "stay bit-identical")
    ap.add_argument("--spec-k", type=int, default=3, metavar="K",
                    help="drafter tokens proposed per speculative round")
    ap.add_argument("--no-spec", action="store_true",
                    help="ignore --draft-model (vanilla-decode baseline)")
    ap.add_argument("--host-blocks", type=int, default=0, metavar="N",
                    help="tiered KV: N-block host tier for spilled cold "
                         "blocks (0 = untiered)")
    ap.add_argument("--kv-pool-blocks", type=int, default=None,
                    help="device pool size in blocks (shrink it to make "
                         "the host tier earn its keep)")
    ap.add_argument("--replica-roles", default=None, metavar="R1,R2,...",
                    help="disaggregated fleet: comma-separated per-replica "
                         "roles (prefill/decode/mixed, one per --replicas); "
                         "prefill replicas migrate finished prompts' KV "
                         "blocks to decode replicas instead of decoding "
                         "locally")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="C",
                    help="prefill prompts in C-token chunks interleaved "
                         "with decode steps (C must be a multiple of the "
                         "16-token block size)")
    ap.add_argument("--inject-faults", default=None, metavar="PLAN",
                    help="deterministic chaos: comma-separated "
                         "site[:action[:after[:count]]] fault specs or "
                         "seed=<int> (e.g. replica.executor:raise:4)")
    ap.add_argument("--max-retries", type=int, default=2, metavar="N",
                    help="multi-replica only: reissue a failed request to "
                         "surviving replicas up to N times before FAILED")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="S",
                    help="cancel any request still unfinished after S "
                         "seconds (typed DeadlineExceeded, KV reclaimed)")
    args = ap.parse_args()

    cfg = arch_registry.smoke(args.arch)
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    spec_kw = {}
    if args.draft_model and not args.no_spec:
        if args.draft_model == args.arch:
            draft_cfg, draft_params = cfg, params
        else:
            draft_cfg = arch_registry.smoke(args.draft_model)
            draft_params = fns_for(draft_cfg).init(draft_cfg,
                                                   jax.random.PRNGKey(1))
        spec_kw = dict(draft_cfg=draft_cfg, draft_params=draft_params,
                       spec_k=args.spec_k)
    rng = np.random.default_rng(0)
    # mixed lengths on purpose: short requests finish early and their slots
    # are refilled immediately (no lock-step waves)
    reqs = [Request(i,
                    rng.integers(0, cfg.vocab_size, size=12).astype(np.int32),
                    max_new_tokens=3 if i % 3 else 9,
                    sampler=greedy() if i % 2 else temperature(0.7, top_k=20,
                                                               seed=i),
                    # interactive tier: jumps the queue, 2s TTFT target
                    priority=1 if i % 3 == 0 else 0,
                    slo_ttft_s=2.0 if i % 3 == 0 else None,
                    deadline_s=args.deadline_s)
            for i in range(args.requests)]

    plan = (FaultPlan.parse(args.inject_faults)
            if args.inject_faults else None)
    roles = (args.replica_roles.split(",") if args.replica_roles
             else ["mixed"] * args.replicas)
    if len(roles) != args.replicas:
        ap.error(f"--replica-roles names {len(roles)} roles for "
                 f"--replicas {args.replicas}")
    replicas = [ServingEngine(cfg, params, max_len=24, batch_slots=4,
                              pool_blocks=args.kv_pool_blocks,
                              host_blocks=args.host_blocks,
                              prefill_chunk=args.prefill_chunk,
                              name=f"replica{i}", fault_plan=plan,
                              role=roles[i], **spec_kw)
                for i in range(args.replicas)]
    if args.replicas == 1:
        stats = replicas[0].serve(reqs)
    else:
        stats = ReplicaRouter(replicas, affinity=not args.no_affinity,
                              steal=not args.no_steal,
                              max_retries=args.max_retries).serve(reqs)
    print(f"{stats.requests} requests -> {stats.tokens} tokens in "
          f"{stats.wall_s:.2f}s  ({stats.tokens_per_s:.1f} tok/s, "
          f"slot occupancy {stats.slot_occupancy:.2f})")
    if args.replicas > 1:
        print(f"router: affinity_hits={stats.router_affinity_hits}  "
              f"steals={stats.router_steals}")
    if stats.spec_proposed:
        print(f"spec: accept_rate={stats.accept_rate:.2f}  "
              f"verify_steps={stats.verify_steps}  "
              f"decode_steps={stats.decode_steps}")
    if stats.kv_spills or stats.kv_fetches:
        print(f"tiering: spills={stats.kv_spills}  "
              f"fetches={stats.kv_fetches}  "
              f"host_hits={stats.prefix_hits_host}")
    if stats.kv_migrations:
        print(f"disagg: migrations={stats.kv_migrations}  "
              f"migrated_blocks={stats.migrated_blocks}")
    if stats.slo_miss_rate is not None:
        print(f"slo miss rate {stats.slo_miss_rate:.2f}  "
              f"preemptions {stats.preemptions}  "
              f"kv_blocks_peak {stats.kv_blocks_peak}")
    if stats.faults_injected or stats.requests_failed or stats.requests_retried:
        print(f"faults: injected={stats.faults_injected}  "
              f"failed={stats.requests_failed}  "
              f"retried={stats.requests_retried}  "
              f"replica_failures={stats.replica_failures}")
    print(tpu_serving_report(stats.tokens_per_s, chips=args.replicas).row())
    for r in reqs[:3]:
        ttft = f"{r.ttft_s:.2f}s" if r.ttft_s is not None else "n/a"
        print(f"  req {r.rid} [{r.state.value}]: {r.output}  ttft={ttft}")


if __name__ == "__main__":
    main()
