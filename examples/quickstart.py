"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a reduced Qwen2.5-style model, runs one forward pass, a few train
steps, then serves a prompt through the batched engine — all on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as arch_registry
from repro.models.registry import fns_for
from repro.optim.optimizers import adamw, warmup_cosine
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import temperature
from repro.training.train_step import make_train_step

# 1. pick an architecture (any of the ten assigned ids; --smoke dims here)
cfg = arch_registry.smoke("qwen2.5-3b")
fns = fns_for(cfg)
params = fns.init(cfg, jax.random.PRNGKey(0))
print(f"arch={cfg.name} params="
      f"{sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")

# 2. forward pass
batch = {
    "tokens": jnp.ones((2, 16), jnp.int32),
    "labels": jnp.ones((2, 16), jnp.int32),
}
logits, aux = fns.forward(cfg, params, batch)
print("logits:", logits.shape, "aux loss:", float(aux))

# 3. a few train steps
opt = adamw(warmup_cosine(3e-3, 5, 20))
step = jax.jit(make_train_step(cfg, opt, accum=1))
opt_state = opt.init(params)
rng = np.random.default_rng(0)
for i in range(10):
    toks = rng.integers(0, cfg.vocab_size, size=(4, 17)).astype(np.int32)
    b = {"tokens": jnp.asarray(toks[:, :-1]),
         "labels": jnp.asarray(toks[:, 1:])}
    params, opt_state, metrics = step(params, opt_state, b)
    if i % 3 == 0:
        print(f"step {i}: loss={float(metrics['loss']):.3f}")

# 4. serve a prompt (prefill + batched decode with a KV cache)
engine = ServingEngine(cfg, params, max_len=24, batch_slots=2)
req = Request(0, np.arange(8, dtype=np.int32), max_new_tokens=6,
              sampler=temperature(0.8, top_k=20))
stats = engine.serve([req])
print("generated tokens:", req.output, f"({stats.tokens_per_s:.1f} tok/s)")
