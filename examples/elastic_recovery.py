"""Elastic recovery demo: lose a device mid-training, shrink the data axis,
re-shard state, continue — the 1000-node posture exercised on 8 fake CPUs.

  PYTHONPATH=src python examples/elastic_recovery.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.configs import registry as arch_registry    # noqa: E402
from repro.data.pipeline import SyntheticTokens        # noqa: E402
from repro.distributed.elastic import (reshard, shrink_batch,   # noqa: E402
                                       surviving_mesh)
from repro.distributed.policy import param_axes        # noqa: E402
from repro.distributed.sharding import rules_for, use_rules  # noqa: E402
from repro.configs.base import ShapeConfig             # noqa: E402
from repro.models.registry import fns_for              # noqa: E402
from repro.optim.optimizers import adamw, constant     # noqa: E402
from repro.training.train_step import make_train_step  # noqa: E402

cfg = arch_registry.smoke("qwen2.5-3b")
fns = fns_for(cfg)
opt = adamw(constant(1e-3))
shape = ShapeConfig("demo", "train", 32, 8)

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = rules_for(cfg, shape, mesh)
params = fns.init(cfg, jax.random.PRNGKey(0))
opt_state = opt.init(params)
data = SyntheticTokens(cfg, batch=8, seq_len=8)
step = jax.jit(make_train_step(cfg, opt, accum=1))

with mesh, use_rules(rules, mesh):
    for i in range(3):
        b = next(iter(data))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
        print(f"[mesh 4x2] step {i}: loss {float(m['loss']):.3f}")

# --- device loss: drop one chip -> lose its whole data row ------------------
lost = {mesh.devices[1, 0].id}
print(f"\nsimulated loss of device {lost} -> re-meshing")
new_mesh = surviving_mesh(mesh, lost)
print(f"surviving mesh: {new_mesh.devices.shape} "
      f"(batch {8} -> {shrink_batch(8, 4, new_mesh.devices.shape[0])})")

new_rules = rules_for(cfg, shape, new_mesh)
axes = param_axes(cfg)
params = reshard(params, axes, new_mesh, new_rules)
opt_state = reshard(opt_state, opt.state_axes(axes), new_mesh, new_rules)

data2 = SyntheticTokens(cfg, batch=shrink_batch(8, 4, 3), seq_len=8, seed=1)
with new_mesh, use_rules(new_rules, new_mesh):
    for i in range(3):
        b = next(iter(data2))
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
        print(f"[mesh 3x2] step {i}: loss {float(m['loss']):.3f}")
print("\nelastic recovery complete — training continued on 6/8 devices")
