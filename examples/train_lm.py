"""End-to-end training driver: ~100M-class model, few hundred steps, with a
mid-run simulated crash + checkpoint auto-resume (deliverable (b)).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs import registry as arch_registry
from repro.data.pipeline import Prefetcher, SyntheticTokens
from repro.distributed.fault import FaultSchedule
from repro.optim.optimizers import adamw, warmup_cosine
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # xlstm-125m's reduced config is the fastest CPU trainer in the pool
    cfg = arch_registry.smoke("xlstm-125m")
    data = Prefetcher(SyntheticTokens(cfg, args.batch, args.seq))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainerConfig(num_steps=args.steps, ckpt_every=50,
                           ckpt_dir=ckpt_dir, log_every=25)
        trainer = Trainer(
            cfg, iter(data), tc,
            optimizer=adamw(warmup_cosine(3e-3, 30, args.steps)),
            fault_schedule=FaultSchedule(
                events={args.steps // 2: "crash"}))   # recovery demo
        history = trainer.train()
    losses = [(h["step"], h["loss"]) for h in history if "loss" in h]
    events = [h for h in history if "event" in h]
    for s, l in losses[:: max(len(losses) // 10, 1)]:
        print(f"step {s:4d}  loss {l:.3f}")
    print(f"crash events recovered: {events}")
    print(f"final loss: {losses[-1][1]:.3f} (from {losses[0][1]:.3f})")
    assert losses[-1][1] < losses[0][1]


if __name__ == "__main__":
    main()
