"""Paper reproduction demo: NCSw-style multi-co-processor offload.

  PYTHONPATH=src python examples/offload_inference.py

Runs GoogLeNet inference three ways through the same split-phase engine:
  1. one real JAX target on this host (the "CPU" column),
  2. 1..8 calibrated Myriad-2 VPU simulants (the paper's scaling law),
  3. throughput-per-watt accounting (paper Eq. 1).
"""
import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.offload import JaxTarget, OffloadEngine, SimTarget
from repro.core.power import PAPER_LATENCY_S, PAPER_TDP_W, report
from repro.data.pipeline import SyntheticImages
from repro.models import googlenet

SCALE = 0.05   # run the calibrated simulation at 20x speed

# --- real inference through the engine --------------------------------------
cfg = arch_registry.GOOGLENET
params = googlenet.init(cfg, jax.random.PRNGKey(0))
fwd = jax.jit(lambda im: googlenet.predict(cfg, params, im)[0])
target = JaxTarget(lambda im: np.asarray(fwd(im)), name="host-cpu",
                   tdp_watts=PAPER_TDP_W["cpu"])
src = SyntheticImages(batch=8, size=64)
batches = [src.sample(8)["images"] for _ in range(4)]
with OffloadEngine([target]) as eng:
    labels, stats = eng.run(batches)
print(f"[host]  {stats.throughput * 8:6.1f} img/s through the engine "
      f"(real GoogLeNet, batch 8)")

# --- paper's multi-VPU scaling ----------------------------------------------
lat = PAPER_LATENCY_S["vpu"] * SCALE
base = None
for n in (1, 2, 4, 8):
    vpus = [SimTarget(f"ncs{i}", compute_s=lat * 0.8, transfer_s=lat * 0.2,
                      tdp_watts=PAPER_TDP_W["vpu"]) for i in range(n)]
    with OffloadEngine(vpus) as eng:
        _, st = eng.run(range(48))
    base = base or st.throughput
    img_s = st.throughput * SCALE
    rep = report("vpu", n, img_s)
    print(f"[vpu x{n}]  speedup {st.throughput / base:4.2f}x   "
          f"{img_s:6.1f} img/s   {rep.items_per_watt:6.2f} img/W")
print("paper Fig 6b/8a: near-ideal scaling to 8 devices; "
      ">3x img/W vs CPU/GPU")
