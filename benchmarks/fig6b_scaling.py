"""Paper Fig 6b: relative performance scaling vs batch/device count.

VPU scaling is near-ideal (the NCSw overlap), CPU/GPU batch scaling is poor
(1.1x / 1.9x at 8).  We reproduce the VPU curve by actually running the
offload engine over 1..8 simulated devices, and the host curves from the
paper's saturation model.  Paper values at n=8: VPU ~7.8x.
"""
from __future__ import annotations

from repro.core.offload import OffloadEngine

from benchmarks.common import (SIM_ITEMS, paper_host_target,
                               paper_vpu_targets, save_artifact)


def run(verbose: bool = True) -> dict:
    vpu = {}
    base = None
    for n in (1, 2, 4, 8):
        with OffloadEngine(paper_vpu_targets(n)) as eng:
            _, st = eng.run(range(SIM_ITEMS))
        if base is None:
            base = st.throughput
        vpu[n] = st.throughput / base
    cpu = {}
    gpu = {}
    for n in (1, 2, 4, 8):
        for kind, d in (("cpu", cpu), ("gpu", gpu)):
            t = paper_host_target(kind, batch=n)
            d[n] = (paper_host_target(kind, 1).compute_s * n) / \
                (t.compute_s * n) * n / n  # speedup = lat1*n / lat(n)
            d[n] = paper_host_target(kind, 1).compute_s * n / t.compute_s
    out = {"vpu_speedup": vpu, "cpu_speedup": cpu, "gpu_speedup": gpu,
           "paper_reference": {"vpu_8": 7.8, "cpu_8": 1.147, "gpu_8": 1.925}}
    if verbose:
        print("fig6b  VPU speedup:", {k: round(v, 2) for k, v in vpu.items()})
        print("fig6b  CPU speedup:", {k: round(v, 2) for k, v in cpu.items()})
        print("fig6b  GPU speedup:", {k: round(v, 2) for k, v in gpu.items()})
    save_artifact("fig6b_scaling", out)
    assert vpu[8] > 6.5, "multi-VPU scaling should be near-ideal"
    return out


if __name__ == "__main__":
    run()
