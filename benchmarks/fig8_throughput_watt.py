"""Paper Fig 8: throughput-per-TDP-watt (Eq. 1) + projected scaling to 16
devices; extended with the TPU-v5e serving analogue (tokens/s/W).

Paper values: 3.97 img/W (VPU) vs 0.55 (CPU) vs 0.93 (GPU); projected
153 img/s at 16 VPUs (1.9x over GPU).
"""
from __future__ import annotations

from repro.core.offload import OffloadEngine
from repro.core.power import PAPER_TDP_W, report

from benchmarks.common import (SIM_ITEMS, SIM_SCALE, paper_host_target,
                               paper_vpu_targets, save_artifact)


def run(verbose: bool = True) -> dict:
    out = {"paper_reference_img_w": {"vpu": 3.97, "cpu": 0.55, "gpu": 0.93}}
    rows = {}
    # measured-through-engine calibrated throughputs
    for n in (1, 4, 8):
        with OffloadEngine(paper_vpu_targets(n)) as eng:
            _, st = eng.run(range(SIM_ITEMS))
        rows[f"vpu_x{n}"] = report("vpu", n, st.throughput * SIM_SCALE)
    for kind in ("cpu", "gpu"):
        with OffloadEngine([paper_host_target(kind, batch=8)]) as eng:
            _, st = eng.run(range(SIM_ITEMS // 8))
        rows[kind] = report(kind, 1, st.throughput * 8 * SIM_SCALE)

    # projected ideal scaling past the 8 devices on hand (paper Fig 8b)
    per_dev = rows["vpu_x8"].items_per_s / 8
    proj16 = per_dev * 16
    out["projected_vpu16_img_s"] = proj16
    out["rows"] = {k: {"items_per_s": r.items_per_s,
                       "tdp_w": r.tdp_watts_total,
                       "items_per_watt": r.items_per_watt} for k, r in rows.items()}
    if verbose:
        for k, r in rows.items():
            print("fig8  ", r.row())
        print(f"fig8   projected 16xVPU: {proj16:.1f} img/s "
              f"(paper: 153.0)")
    save_artifact("fig8_throughput_watt", out)
    vpu_w = rows["vpu_x8"].items_per_watt
    gpu_w = rows["gpu"].items_per_watt
    assert vpu_w / gpu_w > 3.0, "VPU should hold >3x img/W vs GPU (paper)"
    return out


if __name__ == "__main__":
    run()
