"""Shared benchmark helpers: paper-calibrated targets + real CPU targets."""
from __future__ import annotations

import json
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.offload import JaxTarget, OffloadEngine, SimTarget
from repro.core.power import PAPER_LATENCY_S, PAPER_TDP_W
from repro.data.pipeline import SyntheticImages
from repro.models import googlenet
from repro.models.registry import fns_for

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

# time-scale for the calibrated simulation (keeps benchmarks fast while
# preserving the paper's latency RATIOS, which the figures are about)
SIM_SCALE = 0.05
SIM_ITEMS = 60


def save_artifact(name: str, payload: dict) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def paper_vpu_targets(n: int, *, transfer_frac: float = 0.2):
    """n simulated NCS devices with the paper's 100.7 ms single-inference
    latency, split into USB-transfer and SHAVE-compute shares."""
    lat = PAPER_LATENCY_S["vpu"] * SIM_SCALE
    return [SimTarget(f"vpu{i}", compute_s=lat * (1 - transfer_frac),
                      transfer_s=lat * transfer_frac,
                      tdp_watts=PAPER_TDP_W["vpu"]) for i in range(n)]


def paper_host_target(kind: str, batch: int = 1):
    """Simulated CPU/GPU target with the paper's batch-scaling behaviour.

    The paper observed poor batch scaling on the hosts (CPU 1.1x at 8,
    GPU 1.9x at 8): latency(batch) = lat1 * batch / scaling(batch)."""
    lat1 = PAPER_LATENCY_S[kind] * SIM_SCALE
    limit = {"cpu": 1.147, "gpu": 1.925}[kind]
    # smooth saturating speedup matching the paper's 1- and 8-batch points
    speedup = 1.0 + (limit - 1.0) * (batch - 1) / 7.0 if batch > 1 else 1.0
    return SimTarget(f"{kind}-b{batch}", compute_s=lat1 * batch / speedup,
                     tdp_watts=PAPER_TDP_W[kind])


def googlenet_cpu_target(cfg=None, batch: int = 1):
    """REAL GoogLeNet inference on this host (JAX CPU) as an offload target."""
    cfg = cfg or arch_registry.GOOGLENET
    params = googlenet.init(cfg, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda imgs: googlenet.predict(cfg, params, imgs)[2])

    def fn(batch_imgs):
        return np.asarray(fwd(jnp.asarray(batch_imgs)))
    return JaxTarget(fn, name=f"host-googlenet-b{batch}", tdp_watts=80.0)


def image_stream(n: int, batch: int, size: int = 64, seed: int = 0):
    src = SyntheticImages(num_classes=1000, batch=batch, size=size, seed=seed)
    return [src.sample(batch) for _ in range(n)]
