"""Paper Fig 7: FP16-vs-FP32 top-1 error delta + confidence delta.

The paper's quantity is the DIFFERENCE between precisions on identical
inputs (their finding: 0.09 % top-1 delta, 0.44 % mean |confidence| delta —
i.e. FP16 inference is safe).  Pretrained BVLC weights / ILSVRC images are
not available offline, so we evaluate the same estimators on the same
deterministic synthetic set with seeded weights: absolute error rates are
not comparable to the paper, the precision DELTAS are the reproduced
quantity.  bf16 (the TPU-native reduced precision) is reported alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.precision import (confidence_delta, prediction_agreement,
                                  top1_delta, top1_error_rate)
from repro.data.pipeline import SyntheticImages
from repro.models import googlenet

from benchmarks.common import save_artifact

N_IMAGES = 48
BATCH = 8


def _probs(cfg, params, images) -> np.ndarray:
    fwd = jax.jit(lambda im: googlenet.predict(cfg, params, im)[2])
    out = []
    for i in range(0, images.shape[0], BATCH):
        out.append(np.asarray(fwd(jnp.asarray(images[i:i + BATCH]))))
    return np.concatenate(out)


def run(verbose: bool = True) -> dict:
    cfg32 = arch_registry.GOOGLENET
    params = googlenet.init(cfg32, jax.random.PRNGKey(0))
    src = SyntheticImages(num_classes=cfg32.vocab_size, batch=BATCH,
                          size=64, seed=7)
    sample = src.sample(N_IMAGES)
    images, labels = sample["images"], sample["labels"]

    p32 = _probs(cfg32, params, images)
    # reference class for the confidence-delta filter: with untrained
    # weights nothing matches the synthetic labels, so condition on the
    # fp32 model's own top-1 (the paper filters on dataset labels).
    ref_labels = np.argmax(p32, -1)
    out = {"n_images": N_IMAGES,
           "paper_reference": {"top1_delta": 0.0009,
                               "confidence_delta": 0.0044}}
    for name, dtype in (("fp16", "float16"), ("bf16", "bfloat16")):
        cfg_lp = cfg32.replace(compute_dtype=dtype)
        p_lp = _probs(cfg_lp, params, images)
        out[name] = {
            "top1_error_fp32": top1_error_rate(p32, labels),
            "top1_error_lp": top1_error_rate(p_lp, labels),
            "top1_delta": top1_delta(p32, p_lp, labels),
            "confidence_delta": confidence_delta(p32, p_lp, ref_labels),
            "prediction_agreement": prediction_agreement(p32, p_lp),
        }
        if verbose:
            m = out[name]
            print(f"fig7   {name}: top1 Δ={m['top1_delta']:.4f} "
                  f"conf Δ={m['confidence_delta']:.4f} "
                  f"agreement={m['prediction_agreement']:.3f}")
    save_artifact("fig7_error_rate", out)
    # the paper's conclusion: reduced precision barely moves predictions
    assert out["fp16"]["prediction_agreement"] > 0.9
    return out


if __name__ == "__main__":
    run()
