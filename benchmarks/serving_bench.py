"""LM serving benchmark: continuous batching vs the legacy wave decode.

Three scenarios, all real compute on this host, emitted as one JSON
artifact (`artifacts/bench/serving_bench.json`) with stable keys so runs
are comparable across PRs:

  1. `replicas_{1,2}` — replica scaling with least-loaded request pull
     (the paper's multi-NCS protocol at LM scale).
  2. `mixed_wave` / `mixed_continuous` — mixed-length requests (prompts
     6..19 tokens, max_new_tokens drawn from {4, 64}) on one replica with
     4 decode slots.  The wave path lock-steps every wave to its slowest
     member; continuous batching refills a slot the moment its request
     finishes.  `mixed_continuous` runs the paged KV engine with a block
     pool sized <= 50% of the worst-case contiguous footprint;
     `mixed_continuous_contig` is the contiguous A/B twin.
     `continuous_speedup` (paged vs wave) and `paged_vs_contiguous`
     (tokens/s ratio at half the KV memory) are the headline numbers, with
     `kv_pool_frac` / `prefill_compiles` showing where the win comes from
     (paging + prompt-length bucketing vs per-length recompiles).
  3. `arrival` — a seeded arrival process submitted against a running
     engine (service mode): requests admitted mid-stream, the scenario a
     batch-offline API cannot express.
  4. `priority_fifo` / `priority_slo` — the same pressure workload (long
     low-priority decodes wedging the pool, short high-priority requests
     arriving mid-stream) served without and with SLO-aware scheduling;
     `priority_hipri_ttft_p99_speedup` (high-priority p99 TTFT, FIFO /
     SLO) and `priority_tokens_cost_frac` (aggregate tokens/s given up to
     preemption recompute) are the headline pair.
  5. `shared_prefix` / `shared_prefix_nosharing` — N requests over one
     long common prompt prefix with refcounted prefix sharing on and off;
     with sharing the pool peaks below N x prefix-blocks
     (`shared_prefix_nominal_prefix_blocks`) because every request's
     leading table entries point at one shared copy.
  6. `seeded_prefill` / `seeded_prefill_recompute` — the cache-seeded
     prefill A/B: N co-resident requests over one long common prefix,
     served with seeding on (prefill computation starts at the first
     unseeded token) and off (PR-3 behaviour: shared blocks mapped but
     every prompt token re-run into the trash block).
     `prefill_tokens_computed` vs `prefill_tokens_total` is the headline
     pair — seeded compute must drop proportionally to the shared
     fraction — with `seeded_outputs_match` asserting the greedy streams
     are identical token for token.
  7. `chunked_interleave` / `chunked_interleave_off` — a 1024-token
     prompt arriving mid-decode, prefilled in 64-token chunks interleaved
     with decode steps vs all at once; `decode_stall_p99_ms` (the p99 gap
     between consecutive decode steps) is the headline — un-chunked, the
     whole prefill shows up as one giant stall for every active decode.
  8. `router_affinity` / `router_least_loaded` — a shared-prefix workload
     across 2 replicas, routed with fleet-wide prefix-affinity dispatch vs
     the PR-1 request-count least-loaded baseline.  Affinity lands every
     same-prefix request on the replica already holding the blocks, so the
     fleet `prefill_compute_frac` approaches the single-replica seeded
     number (`router_single_replica` is the reference) instead of paying
     the prefix once *per replica*; greedy outputs are asserted identical
     to single-replica serving.
  9. `router_steal` / `router_no_steal` — skewed arrivals: two long
     decodes over a shared prefix pin the affinity owner's slots and pool
     while short same-prefix requests queue behind them and the peer
     idles; with work stealing the idle replica pulls the shorts off the
     backlog, repairing `ttft_p99_ms` (queue position, not CPU
     parallelism, so the win survives this 1-core host) at equal
     deterministic token counts — the relief valve the affinity policy
     relies on.
 11. `tiered_churn` / `tiered_churn_recompute` — distinct shared prefixes
     cycle through a device pool capped at <= 50% of the working set, so
     every prefix is evicted before its revisit.  Tiered, eviction demotes
     the published prefix to the host tier and the revisit *restores* it
     over the async split-phase offload protocol; untiered, the revisit
     recomputes the prompt.  `prefill_compute_frac` is the headline pair
     (asserted lower tiered), greedy outputs asserted bit-identical.
 12. `tiered_longctx` / `tiered_longctx_recompute` — N long-prompt
     requests whose combined logical KV footprint is ~3x the device pool;
     the workload physically cannot keep its KV resident, and the tiered
     engine completes it by riding the demoted history in host memory
     (spills/fetches asserted > 0) instead of re-running the long prefill
     per request.  Plus `pool_microbench`: KVBlockPool hot-path block-ops/s
     across pool sizes spanning 64x (O(1)-per-block audit evidence).
 13. `chaos` — fault-tolerance under a deterministic FaultPlan: one of 2
     tiered replicas has its executor killed mid-serve, a decode commit is
     poisoned on the survivor, and KV fetch transfers are dropped.  The
     recovery contract is *asserted*: every request completes, retried
     requests regenerate bit-identically on the survivor (a retry restarts
     from the bare prompt), the dead replica is quarantined, and both
     block pools drain leak-free.

Wall-clock A/Bs run median-of-`--repeats` (default 3) on a warm engine
via one shared `_median_of` harness (this single-core host's clock
jitters ~25%, so the median policy lives in exactly one place).  Each
scenario reports tokens/s, TTFT p50/p99 (ms), mean TPOT (ms), slot
occupancy, prefill jit compiles, prefill tokens computed vs total,
decode-stall p99, preemptions, prefix-shared table entries, router
affinity hits / steals, SLO miss rate, and (paged) peak KV-pool blocks
and utilization plus the tiering counters (spills, fetches, host prefix
hits, spill bytes, hit rate), plus the fault-tolerance counters
(requests failed/retried, replica failures, shed rejections, faults
injected), plus the disaggregation counters (KV migrations, migrated
blocks).  The headline numbers are also written to repo-root
`BENCH_{5,6,7,9,10}.json` trajectory artifacts via one shared
`_write_headline` writer (stable key order, mandatory `method` string).
`--smoke` runs a tiny 2-replica affinity + steal + spec + tiered-churn
+ disagg + chaos subset in seconds for CI (JSON artifact uploaded by
the tier-1 workflow).
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServeStats, ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.kv_pool import KVBlockPool
from repro.serving.router import (MultiReplicaEngine, ReplicaHealth,
                                  ReplicaRouter)
from repro.serving.sampler import greedy
from repro.serving.scheduler import RequestState

from benchmarks.common import save_artifact


def _median_run(runs: list):
    """THE median-of-N selection policy for wall-clock A/Bs, in one
    place: given ``(wall_s, *rest)`` tuples, return the run with the
    median wall clock.  Token counts must be deterministic across repeats
    so the reported run is output-comparable between A/B arms."""
    return sorted(runs, key=lambda r: r[0])[len(runs) // 2]


def _median_of(repeats: int, run_once):
    """Run ``run_once(rep)`` ``repeats`` times on the caller's (warm)
    engine and report the :func:`_median_run` — this single-core host's
    wall clock jitters ~25%; every scenario that used to hand-roll this
    loop now shares it (multi-arm scenarios that interleave their repeats
    collect runs themselves and call :func:`_median_run` directly)."""
    return _median_run([run_once(rep) for rep in range(repeats)])


def _requests(cfg, n, prompt_len=12, new_tokens=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens, sampler=greedy())
            for i in range(n)]


def _mixed_requests(cfg, n=16, seed=0):
    """Alternating short/long decodes over *varied* prompt lengths: the
    stressor for both continuous batching (ragged finish times) and the
    prefill compile cache (ragged prompt shapes)."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(6, 20)))
                    .astype(np.int32),
                    max_new_tokens=4 if i % 2 else 64, sampler=greedy())
            for i in range(n)]


def _shared_prefix_requests(cfg, n=6, prefix_blocks=2, block=16, seed=4,
                            new_tokens=4, tail=8):
    """N prompts sharing a ``prefix_blocks``-block common prefix with
    distinct ``tail``-token tails: with refcounted prefix sharing the pool
    holds ONE copy of the prefix instead of N.  Everything (prefix and
    tails) derives from ``seed``, so two arms built with the same seed get
    token-identical workloads."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size,
                          size=prefix_blocks * block).astype(np.int32)
    return [Request(i, np.concatenate(
                    [prefix, rng.integers(0, cfg.vocab_size, size=tail)
                     .astype(np.int32)]),
                    max_new_tokens=new_tokens, sampler=greedy())
            for i in range(n)]


def _run_pressure(cfg, params, *, slo_aware: bool, repeats: int = 3):
    """Queue-pressure A/B arm: 8 long low-priority decodes wedge every
    slot and pool block; 4 short requests arrive mid-stream.
    ``slo_aware=True`` marks the late arrivals priority-2 with a TTFT SLO
    (they preempt); ``False`` leaves everything priority-0 (the old FIFO
    behaviour: late arrivals wait behind every queued long decode).

    The median-wall run of ``repeats`` (see :func:`_median_of`) is
    reported: the wall-clock noise would swamp the few-percent
    preemption-recompute cost the A/B is trying to measure."""
    slots, block, low_new = 4, 16, 192
    rows = 8 + low_new - 1
    pool = slots * -(-rows // block)     # lows wedge the pool exactly
    eng = ServingEngine(cfg, params, max_len=8 + low_new + 1,
                        batch_slots=slots, paged=True, block_size=block,
                        pool_blocks=pool)
    # warm the (slots, 1) decode signature and the 16..128 prefill buckets
    # this run can hit (preemption re-prefills prompt + generated tokens)
    eng.serve(_requests(cfg, slots, prompt_len=8, new_tokens=2, seed=99))
    for n, plen in ((2, 20), (2, 33), (2, 65)):
        eng.serve(_requests(cfg, n, prompt_len=plen, new_tokens=2,
                            seed=90 + plen))

    def run_once(rep):
        rng = np.random.default_rng(3 + rep)
        lows = [Request(i, rng.integers(0, cfg.vocab_size, size=8)
                        .astype(np.int32), max_new_tokens=low_new,
                        sampler=greedy())
                for i in range(8)]
        highs = [Request(100 + i, rng.integers(0, cfg.vocab_size, size=8)
                         .astype(np.int32), max_new_tokens=4,
                         sampler=greedy(),
                         priority=2 if slo_aware else 0,
                         slo_ttft_s=0.5 if slo_aware else None)
                 for i in range(4)]
        done = threading.Event()
        remaining = [len(lows) + len(highs)]

        def fin(_, remaining=remaining, done=done):
            remaining[0] -= 1
            if remaining[0] == 0:
                done.set()

        base = eng.begin_window()
        eng.start()
        t0 = time.monotonic()
        for r in lows:
            eng.submit(r, on_finish=fin)
        time.sleep(0.1)              # lows now hold every pool block
        for r in highs:
            eng.submit(r, on_finish=fin)
        done.wait(timeout=180)
        wall = time.monotonic() - t0
        eng.stop()
        stats = eng.collect_window(base, lows + highs, wall)
        # censor a never-served request's TTFT at the window wall so a
        # timeout degrades the number instead of crashing the percentile
        ttfts = [r.ttft_s if r.ttft_s is not None else wall for r in highs]
        p99_ms = round(float(np.percentile(ttfts, 99)) * 1e3, 2)
        return wall, stats, p99_ms

    _, stats, p99_ms = _median_of(repeats, run_once)
    return stats, p99_ms


def _run_seeded(cfg, params, *, seeded: bool, repeats: int = 3):
    """Cache-seeded prefill A/B arm: 6 co-resident requests over one
    64-token (4-block) common prefix with 8-token tails.  ``seeded=True``
    starts prefill computation at the first unseeded token; ``False`` is
    the PR-3 recompute baseline (shared blocks mapped, every prompt token
    re-run into the trash block).  Median-wall run of ``repeats`` on a
    warm engine (:func:`_median_of`); token counts are deterministic, wall
    clock is not."""
    n = 6
    eng = ServingEngine(cfg, params, max_len=64 + 8 + 4 + 1, batch_slots=n,
                        paged=True, block_size=16, seeded_prefill=seeded)
    mk = lambda: _shared_prefix_requests(cfg, n=n, prefix_blocks=4,  # noqa
                                         block=16, seed=21)
    eng.serve(mk())                     # warm: compiles + prefix publish

    def run_once(_rep):
        reqs = mk()
        stats = eng.serve(reqs)
        return stats.wall_s, stats, [r.output for r in reqs]

    _, stats, outputs = _median_of(repeats, run_once)
    return stats, outputs


def _run_spec(cfg, params, *, spec: bool, cache_dtype: str = "bfloat16",
              repeats: int = 3, n: int = 6, slots: int = 4,
              new_tokens: int = 16):
    """Speculative decoding A/B arm: the drafter shares the target's
    weights (self-speculation), so the accept rate is high without a
    second trained model and the step-count win is reproducible on this
    host.  Greedy requests only; the ``spec=False`` baseline must emit
    bit-identical streams — the caller asserts it.  Wall clock is
    *reported*, not asserted: off-TPU the drafter contends for the same
    single core, so the headline here is target-model steps per token."""
    kw = dict(max_len=48, batch_slots=slots, paged=True, block_size=16,
              cache_dtype=cache_dtype)
    if spec:
        kw.update(draft_cfg=cfg, draft_params=params, spec_k=3)
    eng = ServingEngine(cfg, params, **kw)
    mk = lambda: _requests(cfg, n, prompt_len=12,  # noqa: E731
                           new_tokens=new_tokens, seed=33)
    eng.serve(mk())                     # warm: compiles verify + drafter

    def run_once(_rep):
        reqs = mk()
        stats = eng.serve(reqs)
        return stats.wall_s, stats, [r.output for r in reqs]

    _, stats, outputs = _median_of(repeats, run_once)
    return stats, outputs


def _run_chunked(cfg, params, *, chunk: int | None, repeats: int = 3):
    """Chunked-interleave A/B arm: 3 short-prompt decodes are mid-stream
    when a 1024-token prompt arrives.  With ``chunk`` set its prefill runs
    in chunk-token slices between decode steps; with ``None`` it stalls
    every active decode for the whole prefill (the stall is the window's
    ``decode_stall_p99``).  Driven synchronously through the executor
    step so arrival timing is identical across arms, and the workload
    tokens are fixed across repeats so the reported (median-wall) run is
    output-comparable between arms; median-of-``repeats`` on a warm
    engine (:func:`_median_of`)."""
    P = 1024
    eng = ServingEngine(cfg, params, max_len=P + 16, batch_slots=4,
                        paged=True, block_size=16, prefill_chunk=chunk)
    # warm every jitted signature both arms can hit: the (4, 1) decode,
    # short-prompt buckets, and the long prompt's chunk/bucket shapes
    eng.serve(_requests(cfg, 4, prompt_len=8, new_tokens=2, seed=98))
    eng.serve(_requests(cfg, 1, prompt_len=P, new_tokens=2, seed=97))
    rng = np.random.default_rng(31)
    dec_prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
                   for _ in range(3)]
    big_prompt = rng.integers(0, cfg.vocab_size, size=P).astype(np.int32)

    def run_once(rep):
        decs = [Request(10 * rep + i, p, max_new_tokens=48,
                        sampler=greedy())
                for i, p in enumerate(dec_prompts)]
        big = Request(10 * rep + 9, big_prompt, max_new_tokens=4,
                      sampler=greedy())
        base = eng.begin_window()
        t0 = time.monotonic()
        for r in decs:
            eng.scheduler.submit(r)
        for _ in range(8):              # decodes are cruising...
            eng._step()
        eng.scheduler.submit(big)       # ...when the long prompt lands
        while eng.scheduler.has_work():
            eng._step()
        wall = time.monotonic() - t0
        stats = eng.collect_window(base, decs + [big], wall)
        return wall, stats, [r.output for r in decs + [big]]

    _, stats, outputs = _median_of(repeats, run_once)
    return stats, outputs


def _warm_prefix_fleet(cfg, params, n_replicas, *, slots, max_len, block,
                       prefix_blocks):
    """2-to-N warm replicas for the router A/Bs: every replica compiles
    the same prefill/decode signatures *directly* (a routed warmup would
    leave the affinity arm's idle replica cold), using an unrelated warm
    prefix so the measured runs' prefixes are cold in every index."""
    replicas = [ServingEngine(cfg, params, max_len=max_len,
                              batch_slots=slots, paged=True,
                              block_size=block)
                for _ in range(n_replicas)]
    for e in replicas:
        e.serve(_shared_prefix_requests(cfg, n=min(slots, 3),
                                        prefix_blocks=prefix_blocks,
                                        block=block, seed=77,
                                        new_tokens=2))
    return replicas


def _run_router_prefix(cfg, params, *, repeats: int = 3, n: int = 6,
                       prefix_blocks: int = 4, new_tokens: int = 4):
    """Fleet prefix-affinity A/B: ``n`` requests over one fresh common
    prefix, routed across 2 replicas with prefix-affinity dispatch vs the
    PR-1 request-count least-loaded baseline, plus a warm single-replica
    reference.  Affinity lands every same-prefix request on the replica
    that computed the prefix, so the *fleet* ``prefill_compute_frac``
    matches the single-replica seeded number; least-loaded spreads the
    burst and pays the prefix once per replica.  A fresh prefix per repeat
    keeps each measurement first-contact (a warm index would let both
    arms seed everything); greedy outputs are compared per-repeat against
    single-replica serving of the identical workload."""
    block, tail = 16, 8
    max_len = prefix_blocks * block + tail + new_tokens + 1
    arms = {}
    for key, affinity in (("router_affinity", True),
                          ("router_least_loaded", False)):
        replicas = _warm_prefix_fleet(cfg, params, 2, slots=n,
                                      max_len=max_len, block=block,
                                      prefix_blocks=prefix_blocks)
        arms[key] = (ReplicaRouter(replicas, affinity=True, steal=False)
                     if affinity else MultiReplicaEngine(replicas))
    [ref_eng] = _warm_prefix_fleet(cfg, params, 1, slots=n,
                                   max_len=max_len, block=block,
                                   prefix_blocks=prefix_blocks)
    runs = {key: [] for key in arms}
    ref_runs = []
    match = True
    for rep in range(repeats):
        mk = lambda: _shared_prefix_requests(  # noqa: E731
            cfg, n=n, prefix_blocks=prefix_blocks, block=block,
            seed=210 + rep, new_tokens=new_tokens)
        ref_reqs = mk()
        ref_stats = ref_eng.serve(ref_reqs)
        ref_runs.append((ref_stats.wall_s, ref_stats))
        ref_out = [r.output for r in ref_reqs]
        for key, router in arms.items():
            reqs = mk()
            stats = router.serve(reqs)
            runs[key].append((stats.wall_s, stats))
            match = match and [r.output for r in reqs] == ref_out
    return ({key: _median_run(rs)[1] for key, rs in runs.items()},
            _median_run(ref_runs)[1], match)


def _run_router_steal(cfg, params, *, repeats: int = 3, n_short: int = 6,
                      long_tokens: int = 192, short_tokens: int = 8):
    """Skewed-arrivals work-stealing A/B: two *long* decodes over a
    shared prefix pin the affinity owner's both slots — and, by
    construction, its entire block pool — while ``n_short`` short
    same-prefix requests queue behind them and the peer replica idles.
    Without stealing, a short request's first token waits for a long
    decode to finish; with stealing, the idle replica pulls the shorts
    off the backlog and serves them immediately.  TTFT p99 (the shorts'
    wait) is the headline; it is *structural* — queue position, not CPU
    parallelism — so it survives this 1-core host, *provided* the longs
    far outlast the migration: the thief serves every short while the
    longs still run, so no short is left waiting on the (now contended)
    donor.  Token counts are deterministic and equal across arms (greedy
    outputs asserted identical).  The stolen shorts recompute the prefix
    on the thief (its pool does not hold the blocks): that
    prefill-compute cost, visible in ``prefill_tokens_computed``, is the
    price of the latency repair."""
    block, prefix_blocks, tail, slots = 16, 2, 8, 2
    max_len = prefix_blocks * block + tail + long_tokens + 1
    routers = {}
    for key, steal in (("router_steal", True), ("router_no_steal", False)):
        replicas = _warm_prefix_fleet(cfg, params, 2, slots=slots,
                                      max_len=max_len, block=block,
                                      prefix_blocks=prefix_blocks)
        routers[key] = ReplicaRouter(replicas, affinity=True, steal=steal,
                                     steal_interval_s=0.002)
    runs = {key: [] for key in routers}
    match = True
    for rep in range(repeats):
        outs = {}
        for key, router in routers.items():
            reqs = _shared_prefix_requests(
                cfg, n=2 + n_short, prefix_blocks=prefix_blocks,
                block=block, seed=230 + rep, new_tokens=short_tokens)
            for r in reqs[:2]:          # first-arrived pair pins the owner
                r.max_new_tokens = long_tokens
            stats = router.serve(reqs)
            runs[key].append((stats.wall_s, stats))
            outs[key] = [r.output for r in reqs]
        match = match and outs["router_steal"] == outs["router_no_steal"]
    return {key: _median_run(rs)[1] for key, rs in runs.items()}, match


def _run_disagg(cfg, params, *, repeats: int = 3, n_dec: int = 4,
                dec_tokens: int = 64, n_big: int = 1, big_len: int = 1024,
                big_tokens: int = 4, chunk: int = 32):
    """Disaggregated prefill/decode A/B: a burst of ``n_big`` long
    prompts lands on a fleet already decoding ``n_dec`` short requests.
    The ``interleaved_single_pool`` arm is 2 mixed replicas with chunked
    prefill — every long prompt shares a replica (and its step loop)
    with live decodes, so each prefill chunk is a decode stall and each
    interleaved decode step stretches the long prompt's TTFT.  The
    ``disagg`` arm is 1 prefill-role + 1 decode-role replica with the
    same chunk: prompts prefill at full budget with zero decode slots
    contending, then their KV blocks migrate to the decode replica,
    which never computes a prompt token.  Both arms serve identical
    token workloads (median-of-``repeats``, greedy outputs compared
    against a warm single-replica reference) and the migration
    invariants — zero decode-side prompt recompute, leak-free pools on
    both ends after draining — are asserted here, per repeat, not just
    reported."""
    block, slots = 16, n_dec + n_big
    kw = dict(max_len=big_len + big_tokens + block, batch_slots=slots,
              paged=True, block_size=block, prefill_chunk=chunk)
    rng = np.random.default_rng(41)
    dec_prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
                   for _ in range(n_dec)]
    big_prompts = [rng.integers(0, cfg.vocab_size,
                                size=big_len).astype(np.int32)
                   for _ in range(n_big)]

    def mk_reqs(rep):
        shorts = [Request(100 * rep + i, p, max_new_tokens=dec_tokens,
                          sampler=greedy())
                  for i, p in enumerate(dec_prompts)]
        bigs = [Request(100 * rep + 50 + i, p, max_new_tokens=big_tokens,
                        sampler=greedy())
                for i, p in enumerate(big_prompts)]
        return shorts + bigs

    def warm(e):
        # roles are routing policy, not capability: a prefill- or
        # decode-role engine warms standalone like any other, hitting
        # the short-prompt, chunked-long-prompt and decode signatures
        e.serve(_requests(cfg, min(4, slots), prompt_len=8, new_tokens=2,
                          seed=96))
        e.serve([Request(0, big_prompts[0], max_new_tokens=2,
                         sampler=greedy())])

    ref = ServingEngine(cfg, params, **kw)
    warm(ref)
    ref_reqs = mk_reqs(9)
    ref.serve(ref_reqs)
    ref_out = [r.output for r in ref_reqs]

    arms = {}
    for key, roles in (("interleaved_single_pool", ("mixed", "mixed")),
                       ("disagg", ("prefill", "decode"))):
        replicas = [ServingEngine(cfg, params, name=f"{key}-{i}",
                                  role=role, **kw)
                    for i, role in enumerate(roles)]
        for e in replicas:
            warm(e)
        router = ReplicaRouter(replicas, affinity=False, steal=False)
        # warm the *fleet* path too: the disagg arm's adoption scatter
        # compiles per pow-2 block-count bucket, and an unwarmed compile
        # inside the measured window would read as a ~200ms decode stall
        router.serve([Request(9001, dec_prompts[0], max_new_tokens=2,
                              sampler=greedy()),
                      Request(9002, big_prompts[0], max_new_tokens=2,
                              sampler=greedy())])
        arms[key] = (router, replicas)

    runs = {key: [] for key in arms}
    match = True
    windows = []
    for rep in range(repeats):
        for key, (router, replicas) in arms.items():
            reqs = mk_reqs(rep)
            base = (replicas[1].begin_window() if key == "disagg"
                    else None)
            stats = router.serve(reqs)
            match = match and [r.output for r in reqs] == ref_out
            if key == "disagg":
                # the decode replica's own window is the zero-recompute
                # evidence: every prompt token it serves arrived by
                # migration, none were recomputed
                w = replicas[1].collect_window(base, [], stats.wall_s)
                assert w.prefill_tokens_computed == 0, (
                    f"decode replica recomputed "
                    f"{w.prefill_tokens_computed} prompt tokens")
                assert w.kv_migrations == len(reqs), \
                    f"{w.kv_migrations} adoptions for {len(reqs)} requests"
                windows.append(w)
            # serve() drains in-flight migrations before returning, so
            # the export pins must be gone right here, every repeat
            for e in replicas:
                e.pool.assert_leak_free()
            runs[key].append((stats.wall_s, stats))
    for _, (router, _) in arms.items():
        router.stop()
    # the A/B direction is asserted on per-metric medians across
    # repeats, not on the median-wall run's values: a single OS
    # scheduling outlier inside one repeat must not decide the verdict
    med = {key: {"decode_stall_p99_ms": round(float(np.median(
                     [s.decode_stall_p99_s for _, s in rs])) * 1e3, 2),
                 "ttft_p99_ms": round(float(np.median(
                     [s.ttft_p99_s for _, s in rs])) * 1e3, 2)}
           for key, rs in runs.items()}
    return ({key: _median_run(rs)[1] for key, rs in runs.items()},
            med, windows[len(windows) // 2], match)


def _run_migrate_chaos(cfg, params, *, n_dec: int = 3, n_big: int = 1,
                       big_len: int = 64, chunk: int = 32) -> dict:
    """kv.migrate chaos companion: same disaggregated shape, but a
    deterministic :class:`FaultPlan` drops the first two migration
    transfers in flight.  A dropped handoff loses the KV copies — the
    request fails on the source, the router retries it from its bare
    prompt, and greedy regeneration stays bit-identical to an unfaulted
    reference.  Completion, output equality, a nonzero retry count and
    leak-free pools on BOTH ends are asserted."""
    block = 16
    kw = dict(max_len=big_len + 4 + block, batch_slots=n_dec + n_big,
              paged=True, block_size=block, prefill_chunk=chunk)
    rng = np.random.default_rng(43)
    prompts = ([rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
                for _ in range(n_dec)]
               + [rng.integers(0, cfg.vocab_size,
                               size=big_len).astype(np.int32)
                  for _ in range(n_big)])
    mk_reqs = lambda: [Request(i, p, max_new_tokens=4,  # noqa: E731
                               sampler=greedy())
                       for i, p in enumerate(prompts)]
    ref = mk_reqs()
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    plan = FaultPlan([FaultSpec("kv.migrate", "drop", count=2)])
    replicas = [ServingEngine(cfg, params, name="pre0", role="prefill",
                              fault_plan=plan, **kw),
                ServingEngine(cfg, params, name="dec0", role="decode",
                              fault_plan=plan, **kw)]
    router = ReplicaRouter(replicas, affinity=False, steal=False,
                           max_retries=3)
    reqs = mk_reqs()
    stats = router.serve(reqs)
    router.stop()
    assert all(r.state is RequestState.DONE for r in reqs), \
        [(r.rid, r.state, r.error) for r in reqs]
    assert [r.output for r in reqs] == [r.output for r in ref], \
        "post-retry outputs diverged from the unfaulted reference"
    assert stats.requests_retried >= 1, \
        "dropped migrations forced no retry"
    leaks = {}
    for e in replicas:
        leaks[e.name] = e.pool.leak_report()
        e.pool.assert_leak_free()
    return {"migrate_chaos": _summary(stats),
            "migrate_chaos_faults_fired": plan.fired,
            "migrate_chaos_outputs_match_reference": True,
            "migrate_chaos_leak_report": leaks}


def _tiered_churn_requests(cfg, *, groups, visits, prefix_blocks, block,
                           tail, new_tokens, seed):
    """``groups`` distinct shared prefixes revisited ``visits`` times with
    fresh tails per visit, in round-robin order — so by the time a prefix
    is revisited, the intervening groups have churned it out of a small
    device pool.  Everything derives from ``seed``: two arms built with
    the same seed get token-identical workloads."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size,
                             size=prefix_blocks * block).astype(np.int32)
                for _ in range(groups)]
    reqs = []
    for v in range(visits):
        for g, prefix in enumerate(prefixes):
            t = rng.integers(0, cfg.vocab_size, size=tail).astype(np.int32)
            reqs.append(Request(v * groups + g, np.concatenate([prefix, t]),
                                max_new_tokens=new_tokens, sampler=greedy()))
    return reqs


def _run_tiered_churn(cfg, params, *, tiered: bool, repeats: int = 3,
                      groups: int = 4, visits: int = 2,
                      prefix_blocks: int = 3, new_tokens: int = 4):
    """Tiered-KV churn A/B arm: ``groups`` distinct multi-block prefixes
    cycle through a 1-slot engine whose device pool holds <= 50% of the
    working set, so every prefix is evicted before its revisit.  Tiered,
    eviction *demotes* the published prefix to the host tier and the
    revisit restores it over the split-phase offload protocol (prefetch
    issued at admission, overlapped with the decode in flight); untiered,
    the revisit recomputes the whole prompt.  Prefill tokens computed is
    the headline pair; greedy outputs are asserted identical because a
    restored block is the exact bytes that were spilled."""
    block, tail = 16, 8
    # per-request demand: prefix + tail + decode rows
    per_req = (prefix_blocks * block + tail + new_tokens + block - 1) // block
    pool_blocks = per_req + 2           # room to keep SOME prefixes resident
    working_set = groups * per_req
    assert pool_blocks * 2 <= working_set, "churn needs pool <= 50% of set"
    eng = ServingEngine(cfg, params,
                        max_len=prefix_blocks * block + tail + new_tokens + 1,
                        batch_slots=1, paged=True, block_size=block,
                        pool_blocks=pool_blocks,
                        host_blocks=8 * groups * per_req if tiered else 0)
    eng.serve(_tiered_churn_requests(cfg, groups=2, visits=1,
                                     prefix_blocks=prefix_blocks, block=block,
                                     tail=tail, new_tokens=2, seed=9_900))

    def run_once(rep):
        reqs = _tiered_churn_requests(cfg, groups=groups, visits=visits,
                                      prefix_blocks=prefix_blocks,
                                      block=block, tail=tail,
                                      new_tokens=new_tokens, seed=700 + rep)
        t = eng.serve(reqs)
        return t.wall_s, t, [r.output for r in reqs]

    wall, stats, outs = _median_of(repeats, run_once)
    return stats, outs, {"pool_blocks": pool_blocks,
                         "working_set_blocks": working_set}


def _run_tiered_longctx(cfg, params, *, tiered: bool, n: int = 4,
                        prefix_blocks: int = 10, new_tokens: int = 4):
    """Long-context tiering arm: ``n`` requests over one long shared
    prefix whose combined logical KV footprint is several times the
    device pool, served through 1 slot so each request churns its
    predecessor's history out of the pool.  The workload physically
    cannot keep its KV resident — tiered, the demoted prefix rides in the
    host tier and each successor *restores* it instead of re-running the
    long prompt; untiered, every request pays the full prefill again.
    Deterministic (no repeats needed for the headline token counts)."""
    block, tail = 16, 8
    P = prefix_blocks * block + tail
    per_req = (P + new_tokens + block - 1) // block
    pool_blocks = per_req + 2
    logical_blocks = n * per_req
    assert pool_blocks < logical_blocks, "long-context must outsize the pool"
    eng = ServingEngine(cfg, params, max_len=P + new_tokens + 1,
                        batch_slots=1, paged=True, block_size=block,
                        pool_blocks=pool_blocks,
                        host_blocks=4 * logical_blocks if tiered else 0)
    reqs = _tiered_churn_requests(cfg, groups=1, visits=n,
                                  prefix_blocks=prefix_blocks, block=block,
                                  tail=tail, new_tokens=new_tokens, seed=810)
    stats = eng.serve(reqs)
    completed = all(len(r.output) == new_tokens for r in reqs)
    return stats, [r.output for r in reqs], {
        "pool_blocks": pool_blocks, "logical_blocks": logical_blocks,
        "completed": completed}


def _run_chaos(cfg, params, *, n: int = 6, new_tokens: int = 4) -> dict:
    """Fault-tolerance chaos scenario: 2 tiered replicas serve a
    shared-prefix workload while one deterministic :class:`FaultPlan`
    kills replica0's executor mid-stream, poisons one decode commit on
    the survivor, and drops KV fetch transfers.  The router quarantines
    the dead replica and reissues its queued + in-flight requests to the
    survivor; a retried request restarts from its bare prompt, so greedy
    regeneration is *bit-identical* to an unfaulted single-replica
    reference.  The recovery properties are **asserted**, not just
    reported — every request completes, fleet-merged ``requests_retried``
    and ``replica_failures`` are nonzero, and after draining in-flight
    tier IO both pools are leak-free (the tentpole invariant: any fault
    sequence leaves zero leaked blocks)."""
    block, prefix_blocks, tail = 8, 2, 8
    kw = dict(max_len=prefix_blocks * block + tail + new_tokens + 1,
              batch_slots=2, paged=True, block_size=block,
              pool_blocks=10, host_blocks=32)
    mk_reqs = lambda: _shared_prefix_requests(  # noqa: E731
        cfg, n=n, prefix_blocks=prefix_blocks, block=block, seed=61,
        new_tokens=new_tokens)
    ref = mk_reqs()
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    plan = FaultPlan([
        FaultSpec("replica.executor", "raise", after=2, replica="replica0"),
        FaultSpec("engine.decode", "raise", after=6, count=1,
                  replica="replica1"),
        FaultSpec("kv.fetch", "drop", count=2),
    ])
    replicas = [ServingEngine(cfg, params, name=f"replica{i}",
                              fault_plan=plan, **kw) for i in range(2)]
    router = ReplicaRouter(replicas, affinity=False, steal=True,
                           steal_interval_s=0.001, max_retries=2)
    reqs = mk_reqs()
    stats = router.serve(reqs)
    router.stop()
    assert all(r.state is RequestState.DONE for r in reqs), \
        [(r.rid, r.state, r.error) for r in reqs]
    assert [r.output for r in reqs] == [r.output for r in ref], \
        "survivor outputs diverged from the unfaulted reference"
    assert stats.requests_failed == 0, "a request ended FAILED"
    assert stats.requests_retried >= 1, "the replica kill forced no retry"
    assert stats.replica_failures >= 1, "the dead replica went unnoticed"
    assert router.health()[0] is ReplicaHealth.DEAD, \
        "the crashed replica was not quarantined"
    leaks = {}
    for e in replicas:
        e.drain_tier_io()
        leaks[e.name] = e.pool.leak_report()
        e.pool.assert_leak_free()
    out = {"chaos": _summary(stats),
           "chaos_faults_fired": plan.fired,
           "chaos_replica_health": [h.value for h in router.health()],
           "chaos_outputs_match_reference": True,
           "chaos_all_requests_completed": True,
           "chaos_leak_report": leaks}
    return out


def _pool_microbench(sizes=(1 << 10, 1 << 14, 1 << 16), batch: int = 8,
                     cycles: int = 400) -> dict:
    """KVBlockPool hot-path audit evidence: time the full
    reserve -> alloc_reserved -> share -> free -> free block lifecycle at
    pool sizes spanning 64x and report block-ops/s per size.  Every hot
    path is deque/dict based, so ops/s must hold roughly flat as the pool
    grows — a path that scanned the pool would collapse here."""
    out = {}
    for size in sizes:
        pool = KVBlockPool(size, block_size=16)
        t0 = time.perf_counter()
        for _ in range(cycles):
            pool.reserve(batch)
            ids = pool.alloc_reserved(batch)
            pool.share(ids)
            pool.free(ids)
            pool.free(ids)
        dt = time.perf_counter() - t0
        # 5 refcount transitions per block per cycle
        out[f"pool_ops_per_s_{size}_blocks"] = round(cycles * batch * 5 / dt)
    return out


def _summary(stats: ServeStats) -> dict:
    ms = lambda v: round(v * 1e3, 2) if v is not None else None  # noqa: E731
    return {
        "requests": stats.requests, "tokens": stats.tokens,
        "wall_s": round(stats.wall_s, 3),
        "tokens_per_s": round(stats.tokens_per_s, 2),
        "ttft_p50_ms": ms(stats.ttft_p50_s),
        "ttft_p99_ms": ms(stats.ttft_p99_s),
        "tpot_ms": ms(stats.mean_tpot_s),
        "slot_occupancy": round(stats.slot_occupancy, 3),
        "prefills": stats.prefills, "decode_steps": stats.decode_steps,
        "verify_steps": stats.verify_steps,
        "steps_per_token": (round(stats.steps_per_token, 3)
                            if stats.steps_per_token is not None else None),
        "accept_rate": (round(stats.accept_rate, 3)
                        if stats.accept_rate is not None else None),
        "prefill_compiles": stats.prefill_compiles,
        "prefill_tokens_total": stats.prefill_tokens_total,
        "prefill_tokens_computed": stats.prefill_tokens_computed,
        "prefill_compute_frac": (round(stats.prefill_compute_frac, 3)
                                 if stats.prefill_compute_frac is not None
                                 else None),
        "decode_stall_p99_ms": ms(stats.decode_stall_p99_s),
        "preemptions": stats.preemptions,
        "prefix_shared_blocks": stats.prefix_shared_blocks,
        "router_steals": stats.router_steals,
        "router_affinity_hits": stats.router_affinity_hits,
        "slo_miss_rate": (round(stats.slo_miss_rate, 3)
                          if stats.slo_miss_rate is not None else None),
        "kv_blocks_peak": stats.kv_blocks_peak,
        "kv_pool_util": (round(stats.kv_pool_util, 3)
                         if stats.kv_pool_util is not None else None),
        "kv_spills": stats.kv_spills, "kv_fetches": stats.kv_fetches,
        "prefix_hits_host": stats.prefix_hits_host,
        "spill_bytes": stats.spill_bytes,
        "kv_hit_rate": (round(stats.kv_hit_rate, 3)
                        if stats.kv_hit_rate is not None else None),
        "requests_failed": stats.requests_failed,
        "requests_retried": stats.requests_retried,
        "replica_failures": stats.replica_failures,
        "shed_rejections": stats.shed_rejections,
        "faults_injected": stats.faults_injected,
        "kv_migrations": stats.kv_migrations,
        "migrated_blocks": stats.migrated_blocks,
    }


def _kv_state_bytes(eng: ServingEngine) -> int:
    """Device bytes of the engine's batched KV decode state."""
    if eng._state is None:
        eng._state = eng._init_state()
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(eng._state))


def _warmup(eng: ServingEngine, cfg) -> None:
    """Compile prefill/decode outside the timed region.  Uses a full wave
    (= batch_slots requests) so both paths hit the same jitted (slots, 1)
    decode signature before timing starts."""
    eng.serve(_requests(cfg, eng.slots, new_tokens=2, seed=99))
    eng.serve_wave(_requests(cfg, eng.slots, new_tokens=2, seed=99))


def run(verbose: bool = True, repeats: int = 3) -> dict:
    cfg = arch_registry.smoke("qwen2.5-3b")
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    out = {"repeats": repeats}

    # -- scenario 1: replica scaling --------------------------------------
    for n_rep in (1, 2):
        replicas = [ServingEngine(cfg, params, max_len=24, batch_slots=4)
                    for _ in range(n_rep)]
        if n_rep == 1:
            stats = replicas[0].serve(_requests(cfg, 16))
        else:
            stats = MultiReplicaEngine(replicas).serve(_requests(cfg, 16))
        rep = tpu_serving_report(stats.tokens_per_s, chips=n_rep)
        out[f"replicas_{n_rep}"] = dict(
            _summary(stats), tokens_per_s_per_w=rep.items_per_watt)
        if verbose:
            print(f"serving x{n_rep}: {stats.tokens_per_s:.1f} tok/s  "
                  f"{rep.items_per_watt:.4f} tok/s/W  "
                  f"occ={stats.slot_occupancy:.2f}")
    out["replica_scaling_2x"] = (out["replicas_2"]["tokens_per_s"]
                                 / out["replicas_1"]["tokens_per_s"])
    out["note"] = ("this host has ONE CPU core, so two real replicas "
                   "contend for it; protocol-level replica scaling is "
                   "demonstrated with calibrated targets in fig6b (7.7x/8)")

    # -- scenario 2: mixed-length — wave vs continuous, paged vs contiguous
    slots, block = 4, 16
    max_len = 19 + 64 + 1                     # longest prompt + budget
    # paged pool sized <= 50% of the worst-case contiguous footprint
    pool_blocks = (slots * max_len) // (2 * block) - 1
    contig = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                           paged=False)
    paged = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                          paged=True, block_size=block,
                          pool_blocks=pool_blocks)
    _warmup(contig, cfg)
    _warmup(paged, cfg)
    out["mixed_wave"] = _summary(contig.serve_wave(_mixed_requests(cfg)))
    out["mixed_continuous_contig"] = _summary(
        contig.serve(_mixed_requests(cfg)))
    out["mixed_continuous"] = _summary(paged.serve(_mixed_requests(cfg)))
    out["continuous_speedup"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_wave"]["tokens_per_s"], 3)
    out["paged_vs_contiguous"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_continuous_contig"]["tokens_per_s"], 3)
    out["kv_bytes_contiguous"] = _kv_state_bytes(contig)
    out["kv_bytes_paged"] = _kv_state_bytes(paged)
    out["kv_pool_frac"] = round(out["kv_bytes_paged"]
                                / out["kv_bytes_contiguous"], 3)
    if verbose:
        for k in ("mixed_wave", "mixed_continuous_contig",
                  "mixed_continuous"):
            s = out[k]
            print(f"{k}: {s['tokens_per_s']:.1f} tok/s  "
                  f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
                  f"occ={s['slot_occupancy']}  "
                  f"compiles={s['prefill_compiles']}")
        print(f"continuous vs wave speedup: {out['continuous_speedup']:.2f}x")
        print(f"paged vs contiguous: {out['paged_vs_contiguous']:.2f}x "
              f"tok/s at {out['kv_pool_frac']:.0%} of the KV footprint "
              f"(peak util {out['mixed_continuous']['kv_pool_util']})")

    # -- scenario 3: arrival process against a running engine --------------
    eng2 = ServingEngine(cfg, params, max_len=12 + 16, batch_slots=4)
    _warmup(eng2, cfg)
    reqs = _requests(cfg, 12, new_tokens=6, seed=1)
    for i, r in enumerate(reqs):
        r.max_new_tokens = 4 if i % 2 else 16
    rng = np.random.default_rng(2)
    gaps = rng.exponential(0.01, size=len(reqs))
    done = threading.Event()
    remaining = [len(reqs)]

    def fin(_):
        remaining[0] -= 1
        if remaining[0] == 0:
            done.set()

    base = eng2.begin_window()
    eng2.start()
    t0 = time.monotonic()
    for r, gap in zip(reqs, gaps):
        time.sleep(gap)
        # scheduler.submit stamps submitted_at at true submission time
        eng2.submit(r, on_finish=fin)
    done.wait(timeout=120)
    wall = time.monotonic() - t0
    eng2.stop()
    out["arrival"] = _summary(eng2.collect_window(base, reqs, wall))
    if verbose:
        s = out["arrival"]
        print(f"arrival: {s['tokens_per_s']:.1f} tok/s  "
              f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
              f"occ={s['slot_occupancy']}")

    # -- scenario 4: priority under pressure (SLO-aware vs FIFO) -----------
    for key, slo_aware in (("priority_fifo", False), ("priority_slo", True)):
        stats, hipri_p99_ms = _run_pressure(cfg, params, slo_aware=slo_aware,
                                            repeats=repeats)
        s = _summary(stats)
        s["hipri_ttft_p99_ms"] = hipri_p99_ms
        out[key] = s
    out["priority_hipri_ttft_p99_speedup"] = round(
        out["priority_fifo"]["hipri_ttft_p99_ms"]
        / out["priority_slo"]["hipri_ttft_p99_ms"], 3)
    out["priority_tokens_cost_frac"] = round(
        1.0 - (out["priority_slo"]["tokens_per_s"]
               / out["priority_fifo"]["tokens_per_s"]), 3)
    if verbose:
        print(f"priority: hi-pri ttft p99 "
              f"{out['priority_fifo']['hipri_ttft_p99_ms']}ms (fifo) -> "
              f"{out['priority_slo']['hipri_ttft_p99_ms']}ms (slo), "
              f"{out['priority_hipri_ttft_p99_speedup']:.1f}x better at "
              f"{out['priority_tokens_cost_frac']:.1%} tok/s cost "
              f"({out['priority_slo']['preemptions']} preemptions, "
              f"slo miss {out['priority_slo']['slo_miss_rate']})")

    # -- scenario 5: shared prompt prefix (refcounted blocks) --------------
    n_share, prefix_blocks = 6, 2
    for key, sharing in (("shared_prefix", True),
                         ("shared_prefix_nosharing", False)):
        eng = ServingEngine(cfg, params, max_len=2 * 16 + 8 + 4 + 1,
                            batch_slots=n_share, prefix_sharing=sharing)
        _warmup(eng, cfg)
        out[key] = _summary(eng.serve(_shared_prefix_requests(
            cfg, n=n_share, prefix_blocks=prefix_blocks)))
    out["shared_prefix_nominal_prefix_blocks"] = n_share * prefix_blocks
    if verbose:
        s = out["shared_prefix"]
        print(f"shared_prefix: peak {s['kv_blocks_peak']} blocks "
              f"(unshared {out['shared_prefix_nosharing']['kv_blocks_peak']},"
              f" nominal prefix demand "
              f"{out['shared_prefix_nominal_prefix_blocks']}) — "
              f"{s['prefix_shared_blocks']} table entries shared")

    # -- scenario 6: cache-seeded prefill vs full recompute ----------------
    seeded_out = {}
    for key, seeded in (("seeded_prefill", True),
                        ("seeded_prefill_recompute", False)):
        stats, seeded_out[key] = _run_seeded(cfg, params, seeded=seeded,
                                             repeats=repeats)
        out[key] = _summary(stats)
    out["seeded_outputs_match"] = (
        seeded_out["seeded_prefill"] == seeded_out["seeded_prefill_recompute"])
    out["seeded_prefill_compute_frac"] = round(
        out["seeded_prefill"]["prefill_tokens_computed"]
        / out["seeded_prefill_recompute"]["prefill_tokens_computed"], 3)
    if verbose:
        s, r = out["seeded_prefill"], out["seeded_prefill_recompute"]
        print(f"seeded_prefill: {s['prefill_tokens_computed']}"
              f"/{s['prefill_tokens_total']} prompt tokens computed vs "
              f"{r['prefill_tokens_computed']} recomputed "
              f"({out['seeded_prefill_compute_frac']:.0%} of baseline), "
              f"outputs match: {out['seeded_outputs_match']}")

    # -- scenario 7: chunked prefill interleaved with decode ---------------
    chunk_out = {}
    for key, chunk in (("chunked_interleave", 64),
                       ("chunked_interleave_off", None)):
        stats, chunk_out[key] = _run_chunked(cfg, params, chunk=chunk,
                                             repeats=repeats)
        out[key] = _summary(stats)
    out["chunked_outputs_match"] = (
        chunk_out["chunked_interleave"] == chunk_out["chunked_interleave_off"])
    out["chunked_stall_p99_improvement"] = round(
        out["chunked_interleave_off"]["decode_stall_p99_ms"]
        / out["chunked_interleave"]["decode_stall_p99_ms"], 3)
    if verbose:
        c, u = out["chunked_interleave"], out["chunked_interleave_off"]
        print(f"chunked_interleave: decode stall p99 "
              f"{u['decode_stall_p99_ms']}ms (off) -> "
              f"{c['decode_stall_p99_ms']}ms (chunk 64), "
              f"{out['chunked_stall_p99_improvement']:.1f}x better, "
              f"outputs match: {out['chunked_outputs_match']}")

    # -- scenario 8: fleet prefix affinity vs least-loaded dispatch --------
    router_stats, ref_stats, router_match = _run_router_prefix(
        cfg, params, repeats=repeats)
    for key, stats in router_stats.items():
        out[key] = _summary(stats)
    out["router_single_replica"] = _summary(ref_stats)
    out["router_outputs_match_single"] = router_match
    if verbose:
        a = out["router_affinity"]
        b = out["router_least_loaded"]
        s = out["router_single_replica"]
        print(f"router_affinity: fleet prefill frac "
              f"{a['prefill_compute_frac']} vs {b['prefill_compute_frac']} "
              f"least-loaded (single-replica seeded "
              f"{s['prefill_compute_frac']}), "
              f"{a['router_affinity_hits']} affinity hits, outputs match "
              f"single-replica: {router_match}")

    # -- scenario 9: work stealing under an affinity-skewed backlog --------
    steal_stats, steal_match = _run_router_steal(cfg, params,
                                                 repeats=repeats)
    for key, stats in steal_stats.items():
        out[key] = _summary(stats)
    out["router_steal_outputs_match"] = steal_match
    out["router_steal_ttft_p99_improvement"] = round(
        out["router_no_steal"]["ttft_p99_ms"]
        / out["router_steal"]["ttft_p99_ms"], 3)
    if verbose:
        st, ns = out["router_steal"], out["router_no_steal"]
        print(f"router_steal: ttft p99 {ns['ttft_p99_ms']}ms (no steal) -> "
              f"{st['ttft_p99_ms']}ms "
              f"({out['router_steal_ttft_p99_improvement']:.1f}x better, "
              f"{st['router_steals']} steals, tokens {st['tokens']} vs "
              f"{ns['tokens']}, outputs match: {steal_match})")

    # -- scenario 10: speculative decoding (draft/verify on the paged pool)
    spec_out = {}
    for key, spec in (("spec_decode", True), ("spec_decode_off", False)):
        stats, spec_out[key] = _run_spec(cfg, params, spec=spec,
                                         repeats=repeats)
        out[key] = _summary(stats)
    out["spec_outputs_match"] = (
        spec_out["spec_decode"] == spec_out["spec_decode_off"])
    assert out["spec_outputs_match"], \
        "speculative greedy streams diverged from the vanilla baseline"
    out["spec_target_steps"] = (out["spec_decode"]["decode_steps"]
                                + out["spec_decode"]["verify_steps"])
    out["spec_baseline_steps"] = out["spec_decode_off"]["decode_steps"]
    assert out["spec_target_steps"] < out["spec_baseline_steps"], (
        f"speculation must cut target-model steps "
        f"({out['spec_target_steps']} vs {out['spec_baseline_steps']})")
    if verbose:
        s, b = out["spec_decode"], out["spec_decode_off"]
        print(f"spec_decode: {out['spec_baseline_steps']} -> "
              f"{out['spec_target_steps']} target steps "
              f"(accept rate {s['accept_rate']}, "
              f"{b['steps_per_token']} -> {s['steps_per_token']} "
              f"steps/token), wall {b['wall_s']}s -> {s['wall_s']}s, "
              f"outputs match: {out['spec_outputs_match']}")

    # -- scenario 11: tiered KV churn vs recompute (host-offloaded blocks)
    tier_out = {}
    for key, tiered in (("tiered_churn", True),
                        ("tiered_churn_recompute", False)):
        stats, tier_out[key], shape = _run_tiered_churn(
            cfg, params, tiered=tiered, repeats=repeats)
        out[key] = _summary(stats)
    out["tiered_pool_blocks"] = shape["pool_blocks"]
    out["tiered_working_set_blocks"] = shape["working_set_blocks"]
    out["tiered_outputs_match"] = (
        tier_out["tiered_churn"] == tier_out["tiered_churn_recompute"])
    assert out["tiered_outputs_match"], \
        "tiered greedy streams diverged from the recompute baseline"
    assert out["tiered_churn"]["prefix_hits_host"] > 0, \
        "churn never restored a prefix block from the host tier"
    assert (out["tiered_churn"]["prefill_compute_frac"]
            < out["tiered_churn_recompute"]["prefill_compute_frac"]), (
        f"tiering must cut the prefill compute fraction "
        f"({out['tiered_churn']['prefill_compute_frac']} vs "
        f"{out['tiered_churn_recompute']['prefill_compute_frac']})")
    if verbose:
        t, r = out["tiered_churn"], out["tiered_churn_recompute"]
        print(f"tiered_churn: prefill frac {t['prefill_compute_frac']} vs "
              f"{r['prefill_compute_frac']} recompute (pool "
              f"{out['tiered_pool_blocks']}/{out['tiered_working_set_blocks']}"
              f" working-set blocks), {t['kv_spills']} spills "
              f"{t['kv_fetches']} fetches {t['prefix_hits_host']} host hits "
              f"(hit rate {t['kv_hit_rate']}), outputs match: "
              f"{out['tiered_outputs_match']}")

    # -- scenario 12: long-context KV footprint >> device pool -------------
    lc_out = {}
    for key, tiered in (("tiered_longctx", True),
                        ("tiered_longctx_recompute", False)):
        stats, lc_out[key], shape = _run_tiered_longctx(cfg, params,
                                                        tiered=tiered)
        out[key] = _summary(stats)
        out[f"{key}_completed"] = shape["completed"]
        assert shape["completed"], f"{key}: long-context serve incomplete"
    out["longctx_pool_blocks"] = shape["pool_blocks"]
    out["longctx_logical_blocks"] = shape["logical_blocks"]
    out["longctx_outputs_match"] = (
        lc_out["tiered_longctx"] == lc_out["tiered_longctx_recompute"])
    assert out["longctx_outputs_match"], \
        "long-context tiered streams diverged from the recompute baseline"
    assert out["tiered_longctx"]["kv_spills"] > 0 \
        and out["tiered_longctx"]["kv_fetches"] > 0, \
        "long-context run never exercised the spill/fetch path"
    assert (out["tiered_longctx"]["prefill_tokens_computed"]
            < out["tiered_longctx_recompute"]["prefill_tokens_computed"])
    if verbose:
        t = out["tiered_longctx"]
        r = out["tiered_longctx_recompute"]
        print(f"tiered_longctx: {out['longctx_logical_blocks']} logical KV "
              f"blocks through a {out['longctx_pool_blocks']}-block device "
              f"pool; prefill {t['prefill_tokens_computed']}"
              f"/{t['prefill_tokens_total']} computed vs "
              f"{r['prefill_tokens_computed']} recomputed, outputs match: "
              f"{out['longctx_outputs_match']}")

    # -- scenario 13: chaos — replica kill + poison decode + KV-fetch drop -
    out.update(_run_chaos(cfg, params))
    if verbose:
        c = out["chaos"]
        print(f"chaos: {c['requests']} requests completed through "
              f"{out['chaos_faults_fired']} injected faults "
              f"({c['requests_retried']} retried, "
              f"{c['replica_failures']} replica failures, health "
              f"{out['chaos_replica_health']}), outputs match reference: "
              f"{out['chaos_outputs_match_reference']}, leak-free pools")

    # -- scenario 14: disaggregated prefill/decode fleet (KV migration) ----
    disagg_stats, disagg_med, dec_window, disagg_match = _run_disagg(
        cfg, params, repeats=max(repeats, 5))
    for key, stats in disagg_stats.items():
        out[key] = _summary(stats)
        out[key].update(disagg_med[key])   # asserted per-metric medians
    out["disagg_outputs_match"] = disagg_match
    assert disagg_match, \
        "disaggregated greedy outputs diverged from single-replica serving"
    out["disagg_migrations"] = out["disagg"]["kv_migrations"]
    out["disagg_migrated_blocks"] = out["disagg"]["migrated_blocks"]
    out["disagg_decode_replica_prefill_tokens_computed"] = \
        dec_window.prefill_tokens_computed
    out["disagg_stall_p99_improvement"] = round(
        disagg_med["interleaved_single_pool"]["decode_stall_p99_ms"]
        / disagg_med["disagg"]["decode_stall_p99_ms"], 3)
    out["disagg_ttft_p99_improvement"] = round(
        disagg_med["interleaved_single_pool"]["ttft_p99_ms"]
        / disagg_med["disagg"]["ttft_p99_ms"], 3)
    assert out["disagg_stall_p99_improvement"] > 1.0, (
        f"disaggregation must cut decode-stall p99 "
        f"({out['disagg']['decode_stall_p99_ms']}ms vs interleaved "
        f"{out['interleaved_single_pool']['decode_stall_p99_ms']}ms)")
    assert out["disagg_ttft_p99_improvement"] > 1.0, (
        f"disaggregation must cut TTFT p99 "
        f"({out['disagg']['ttft_p99_ms']}ms vs interleaved "
        f"{out['interleaved_single_pool']['ttft_p99_ms']}ms)")
    if verbose:
        d, i = out["disagg"], out["interleaved_single_pool"]
        print(f"disagg: decode stall p99 {i['decode_stall_p99_ms']}ms "
              f"(interleaved) -> {d['decode_stall_p99_ms']}ms "
              f"({out['disagg_stall_p99_improvement']:.1f}x better), "
              f"ttft p99 {i['ttft_p99_ms']}ms -> {d['ttft_p99_ms']}ms "
              f"({out['disagg_ttft_p99_improvement']:.1f}x better), "
              f"{d['kv_migrations']} migrations "
              f"({d['migrated_blocks']} blocks), decode-side prompt "
              f"recompute {out['disagg_decode_replica_prefill_tokens_computed']}"
              f" tokens, outputs match: {disagg_match}")

    out.update(_run_migrate_chaos(cfg, params))
    if verbose:
        m = out["migrate_chaos"]
        print(f"migrate_chaos: {m['requests']} requests completed through "
              f"{out['migrate_chaos_faults_fired']} dropped migrations "
              f"({m['requests_retried']} retried), outputs match "
              f"reference: {out['migrate_chaos_outputs_match_reference']}, "
              f"leak-free pools")

    # -- KV pool hot-path micro-bench --------------------------------------
    out["pool_microbench"] = _pool_microbench()
    if verbose:
        print(f"pool_microbench: {out['pool_microbench']}")

    save_artifact("serving_bench", out)
    _save_bench5(out)
    _save_bench6(out)
    _save_bench7(out)
    _save_bench9(out)
    _save_bench10(out)
    return out


def run_smoke(verbose: bool = True) -> dict:
    """CI-sized subset: 2 replicas, one affinity case and one steal case,
    seconds not minutes, with the A/B directions *asserted* — a routing
    regression fails the build instead of drifting a JSON number.  The
    summary lands in `artifacts/bench/serving_bench_smoke.json` (uploaded
    as a build artifact by the tier-1 workflow)."""
    cfg = arch_registry.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    out = {"smoke": True}

    router_stats, ref_stats, match = _run_router_prefix(
        cfg, params, repeats=1, n=4, prefix_blocks=2, new_tokens=2)
    for key, stats in router_stats.items():
        out[key] = _summary(stats)
    out["router_single_replica"] = _summary(ref_stats)
    out["router_outputs_match_single"] = match
    aff = out["router_affinity"]["prefill_compute_frac"]
    base = out["router_least_loaded"]["prefill_compute_frac"]
    assert match, "routed greedy outputs diverged from single-replica"
    assert aff < base, (
        f"affinity routing must cut the fleet prefill compute fraction "
        f"(affinity {aff} vs least-loaded {base})")
    if verbose:
        print(f"smoke affinity: fleet prefill frac {aff} vs {base} "
              f"least-loaded, outputs match: {match}")

    steal_stats, steal_match = _run_router_steal(cfg, params, repeats=1,
                                                 n_short=4, long_tokens=96,
                                                 short_tokens=4)
    for key, stats in steal_stats.items():
        out[key] = _summary(stats)
    out["router_steal_outputs_match"] = steal_match
    assert steal_match, "stealing changed greedy outputs"
    assert out["router_steal"]["router_steals"] >= 1, \
        "idle replica never stole from the backlogged peer"
    assert out["router_steal"]["tokens"] == out["router_no_steal"]["tokens"]
    if verbose:
        print(f"smoke steal: {out['router_steal']['router_steals']} steals, "
              f"ttft p99 {out['router_no_steal']['ttft_p99_ms']}ms -> "
              f"{out['router_steal']['ttft_p99_ms']}ms, outputs match: "
              f"{steal_match}")

    # speculative decoding: tiny self-speculation case, bf16 and int8 —
    # bit-identicality and the step cut are the PR-6 acceptance criteria,
    # so both are *asserted* here, not just reported
    for dtype, tag in (("bfloat16", "spec_decode"), ("int8",
                                                     "spec_decode_int8")):
        s_on, o_on = _run_spec(cfg, params, spec=True, cache_dtype=dtype,
                               repeats=1, n=2, slots=2, new_tokens=8)
        s_off, o_off = _run_spec(cfg, params, spec=False, cache_dtype=dtype,
                                 repeats=1, n=2, slots=2, new_tokens=8)
        out[tag] = _summary(s_on)
        out[f"{tag}_off"] = _summary(s_off)
        assert o_on == o_off, \
            f"speculative {dtype} streams diverged from vanilla greedy"
        assert s_on.accept_rate is not None and s_on.accept_rate > 0, \
            f"self-speculation accepted nothing ({dtype})"
        assert s_on.decode_steps + s_on.verify_steps < s_off.decode_steps, (
            f"speculation must cut target steps ({dtype}: "
            f"{s_on.decode_steps + s_on.verify_steps} vs "
            f"{s_off.decode_steps})")
        if verbose:
            print(f"smoke {tag}: {s_off.decode_steps} -> "
                  f"{s_on.decode_steps + s_on.verify_steps} target steps, "
                  f"accept rate {s_on.accept_rate:.2f}, outputs match: "
                  f"{o_on == o_off}")

    # tiered KV cache: tiny churn A/B — bit-identical restore and a lower
    # prefill compute fraction are the PR-7 acceptance criteria, asserted
    tier_out = {}
    for tag, tiered in (("tiered_churn", True),
                        ("tiered_churn_recompute", False)):
        stats, tier_out[tag], _shape = _run_tiered_churn(
            cfg, params, tiered=tiered, repeats=1, groups=4, visits=2,
            prefix_blocks=2, new_tokens=2)
        out[tag] = _summary(stats)
    assert tier_out["tiered_churn"] == tier_out["tiered_churn_recompute"], \
        "tiered greedy streams diverged from the recompute baseline"
    assert out["tiered_churn"]["prefix_hits_host"] > 0, \
        "churn never restored a prefix block from the host tier"
    assert (out["tiered_churn"]["prefill_tokens_computed"]
            < out["tiered_churn_recompute"]["prefill_tokens_computed"]), (
        "tiering must cut prefill compute "
        f"({out['tiered_churn']['prefill_tokens_computed']} vs "
        f"{out['tiered_churn_recompute']['prefill_tokens_computed']})")
    # disaggregated prefill/decode smoke: 1 prefill-role + 1 decode-role
    # replica vs 2 interleaved mixed replicas — zero decode-side prompt
    # recompute and leak-free pools are asserted inside _run_disagg per
    # repeat; the decode-stall direction is asserted here (TTFT p99 is
    # reported, not asserted: at smoke scale it sits inside this 1-core
    # host's wall-clock jitter — the full run asserts it)
    disagg_stats, _, dec_window, disagg_match = _run_disagg(
        cfg, params, repeats=1, n_dec=3, dec_tokens=24, n_big=1,
        big_len=128, chunk=32)
    for key, stats in disagg_stats.items():
        out[key] = _summary(stats)
    out["disagg_outputs_match"] = disagg_match
    out["disagg_decode_replica_prefill_tokens_computed"] = \
        dec_window.prefill_tokens_computed
    assert disagg_match, \
        "disaggregated greedy outputs diverged from single-replica serving"
    assert out["disagg"]["kv_migrations"] == 4, \
        f"expected 4 migrations, saw {out['disagg']['kv_migrations']}"
    assert (out["disagg"]["decode_stall_p99_ms"]
            < out["interleaved_single_pool"]["decode_stall_p99_ms"]), (
        f"disaggregation must cut decode-stall p99 "
        f"({out['disagg']['decode_stall_p99_ms']}ms vs interleaved "
        f"{out['interleaved_single_pool']['decode_stall_p99_ms']}ms)")
    if verbose:
        d, i = out["disagg"], out["interleaved_single_pool"]
        print(f"smoke disagg: decode stall p99 {i['decode_stall_p99_ms']}ms "
              f"(interleaved) -> {d['decode_stall_p99_ms']}ms, ttft p99 "
              f"{i['ttft_p99_ms']}ms -> {d['ttft_p99_ms']}ms, "
              f"{d['kv_migrations']} migrations, decode-side recompute "
              f"{out['disagg_decode_replica_prefill_tokens_computed']} "
              f"tokens, outputs match: {disagg_match}")

    # fault-tolerance chaos smoke: kill 1 of 2 replicas mid-serve, poison a
    # decode on the survivor, drop KV fetches — completion, bit-identical
    # survivor outputs, quarantine, and leak-free pools are asserted inside
    out.update(_run_chaos(cfg, params))
    if verbose:
        c = out["chaos"]
        print(f"smoke chaos: {c['requests']} requests completed through "
              f"{out['chaos_faults_fired']} injected faults "
              f"({c['requests_retried']} retried, "
              f"{c['replica_failures']} replica failures, health "
              f"{out['chaos_replica_health']})")

    out["pool_microbench"] = _pool_microbench(sizes=(1 << 10, 1 << 14),
                                              cycles=100)
    if verbose:
        t = out["tiered_churn"]
        print(f"smoke tiered: prefill "
              f"{t['prefill_tokens_computed']}/{t['prefill_tokens_total']} "
              f"computed vs "
              f"{out['tiered_churn_recompute']['prefill_tokens_computed']} "
              f"recomputed, {t['kv_spills']} spills {t['kv_fetches']} "
              f"fetches {t['prefix_hits_host']} host hits, outputs match: "
              f"{tier_out['tiered_churn'] == tier_out['tiered_churn_recompute']}")
        print(f"smoke pool_microbench: {out['pool_microbench']}")

    save_artifact("serving_bench_smoke", out)
    return out


def _write_headline(pr: int, title: str, **metrics) -> str:
    """THE writer for the repo-root ``BENCH_{pr}.json`` trajectory
    artifacts: the payload is ``{"pr", "title", *metrics, "method"}``
    in the call site's insertion order with ``method`` forced last, so
    regenerated artifacts diff cleanly.  Every headline must say how it
    was measured — a missing or empty ``method`` is an error here, not
    a silent omission in one hand-rolled writer."""
    method = metrics.pop("method", "")
    if not str(method).strip():
        raise ValueError(f"BENCH_{pr}.json needs a non-empty 'method' "
                         f"describing how the headline was measured")
    path = os.path.join(os.path.dirname(__file__), "..", f"BENCH_{pr}.json")
    with open(path, "w") as f:
        json.dump({"pr": pr, "title": title, **metrics, "method": method},
                  f, indent=1)
    return path


def _save_bench5(out: dict) -> str:
    return _write_headline(
        5,
        "replica router: prefix-affinity dispatch, block-aware "
        "load, work stealing",
        router_affinity_prefill_compute_frac=(
            out["router_affinity"]["prefill_compute_frac"]),
        router_least_loaded_prefill_compute_frac=(
            out["router_least_loaded"]["prefill_compute_frac"]),
        single_replica_seeded_prefill_compute_frac=(
            out["router_single_replica"]["prefill_compute_frac"]),
        router_affinity_hits=out["router_affinity"]["router_affinity_hits"],
        router_outputs_match_single=out["router_outputs_match_single"],
        router_steal_ttft_p99_ms=out["router_steal"]["ttft_p99_ms"],
        router_no_steal_ttft_p99_ms=out["router_no_steal"]["ttft_p99_ms"],
        router_steal_ttft_p99_improvement=(
            out["router_steal_ttft_p99_improvement"]),
        router_steals=out["router_steal"]["router_steals"],
        router_steal_outputs_match=out["router_steal_outputs_match"],
        method=f"median-of-{out.get('repeats', 3)} repeats on warm "
               f"engines (single-core host wall clock jitters ~25%); "
               f"token counts and output equality are deterministic; "
               f"fresh prefix per repeat so every measurement is "
               f"first-contact",
    )


def _save_bench6(out: dict) -> str:
    return _write_headline(
        6,
        "speculative decoding on the paged pool: draft/verify "
        "slots, batched multi-token verify, bit-identical greedy "
        "acceptance",
        spec_accept_rate=out["spec_decode"]["accept_rate"],
        spec_target_steps=out["spec_target_steps"],
        baseline_target_steps=out["spec_baseline_steps"],
        spec_steps_per_token=out["spec_decode"]["steps_per_token"],
        baseline_steps_per_token=out["spec_decode_off"]["steps_per_token"],
        spec_tokens_per_s=out["spec_decode"]["tokens_per_s"],
        baseline_tokens_per_s=out["spec_decode_off"]["tokens_per_s"],
        spec_wall_s=out["spec_decode"]["wall_s"],
        baseline_wall_s=out["spec_decode_off"]["wall_s"],
        spec_outputs_match=out["spec_outputs_match"],
        method="self-speculation (drafter = target weights, k=3) over "
               "greedy requests on a warm engine; streams asserted "
               "bit-identical to the non-speculative baseline and "
               "target-model steps asserted strictly fewer; wall clock "
               "reported, not asserted — off-TPU the drafter shares "
               "this host's single core, so step reduction is the "
               "headline",
    )


def _save_bench7(out: dict) -> str:
    return _write_headline(
        7,
        "tiered KV cache: host-offloaded blocks with async "
        "spill/prefetch over the split-phase offload protocol",
        churn_tiered_prefill_compute_frac=(
            out["tiered_churn"]["prefill_compute_frac"]),
        churn_recompute_prefill_compute_frac=(
            out["tiered_churn_recompute"]["prefill_compute_frac"]),
        churn_prefix_hits_host=out["tiered_churn"]["prefix_hits_host"],
        churn_kv_spills=out["tiered_churn"]["kv_spills"],
        churn_kv_fetches=out["tiered_churn"]["kv_fetches"],
        churn_spill_bytes=out["tiered_churn"]["spill_bytes"],
        churn_kv_hit_rate=out["tiered_churn"]["kv_hit_rate"],
        churn_pool_blocks=out["tiered_pool_blocks"],
        churn_working_set_blocks=out["tiered_working_set_blocks"],
        churn_outputs_match=out["tiered_outputs_match"],
        longctx_logical_blocks=out["longctx_logical_blocks"],
        longctx_pool_blocks=out["longctx_pool_blocks"],
        longctx_tiered_prefill_tokens_computed=(
            out["tiered_longctx"]["prefill_tokens_computed"]),
        longctx_recompute_prefill_tokens_computed=(
            out["tiered_longctx_recompute"]["prefill_tokens_computed"]),
        longctx_completed=out["tiered_longctx_completed"],
        longctx_outputs_match=out["longctx_outputs_match"],
        pool_microbench=out["pool_microbench"],
        method=f"median-of-{out.get('repeats', 3)} repeats on warm "
               f"engines; device pool capped below the working set so "
               f"eviction demotes published prefixes to the host tier "
               f"and revisits restore them over the async offload "
               f"protocol; greedy outputs asserted bit-identical to the "
               f"untiered recompute baseline and prefill compute "
               f"asserted strictly lower — token counts deterministic, "
               f"wall clock reported not asserted (1-core host)",
    )


def _save_bench9(out: dict) -> str:
    c = out["chaos"]
    return _write_headline(
        9,
        "fault-tolerant serving: deterministic fault injection, "
        "poison isolation, replica quarantine, leak-free retry",
        chaos_requests_completed=c["requests"],
        chaos_requests_failed=c["requests_failed"],
        chaos_requests_retried=c["requests_retried"],
        chaos_replica_failures=c["replica_failures"],
        chaos_faults_fired=out["chaos_faults_fired"],
        chaos_replica_health=out["chaos_replica_health"],
        chaos_outputs_match_reference=out["chaos_outputs_match_reference"],
        chaos_leak_report=out["chaos_leak_report"],
        method="2 tiered replicas under a deterministic FaultPlan "
               "(replica0 executor killed mid-serve, one decode commit "
               "poisoned on the survivor, KV fetch transfers dropped); "
               "every request must complete, retried requests restart "
               "from the bare prompt so greedy outputs are asserted "
               "bit-identical to an unfaulted single-replica "
               "reference, the dead replica is asserted quarantined, "
               "and both block pools are asserted leak-free after "
               "draining in-flight tier IO",
    )


def _save_bench10(out: dict) -> str:
    d, i = out["disagg"], out["interleaved_single_pool"]
    return _write_headline(
        10,
        "disaggregated prefill/decode fleet with live KV-block "
        "migration",
        disagg_decode_stall_p99_ms=d["decode_stall_p99_ms"],
        interleaved_decode_stall_p99_ms=i["decode_stall_p99_ms"],
        disagg_stall_p99_improvement=out["disagg_stall_p99_improvement"],
        disagg_ttft_p99_ms=d["ttft_p99_ms"],
        interleaved_ttft_p99_ms=i["ttft_p99_ms"],
        disagg_ttft_p99_improvement=out["disagg_ttft_p99_improvement"],
        disagg_migrations=out["disagg_migrations"],
        disagg_migrated_blocks=out["disagg_migrated_blocks"],
        disagg_decode_replica_prefill_tokens_computed=(
            out["disagg_decode_replica_prefill_tokens_computed"]),
        disagg_outputs_match=out["disagg_outputs_match"],
        migrate_chaos_requests_retried=(
            out["migrate_chaos"]["requests_retried"]),
        migrate_chaos_outputs_match_reference=(
            out["migrate_chaos_outputs_match_reference"]),
        migrate_chaos_leak_report=out["migrate_chaos_leak_report"],
        method=f"per-metric medians across max({out.get('repeats', 3)}, "
               f"5) repeats on warm fleets: a 1024-token prompt lands "
               f"on a fleet already decoding short requests; the disagg "
               f"arm "
               f"(1 prefill-role + 1 decode-role replica, KV blocks "
               f"migrated at prefill completion) is compared against "
               f"an interleaved arm (2 mixed replicas, same chunked "
               f"prefill) — decode-stall p99 and TTFT p99 asserted "
               f"better, greedy outputs asserted bit-identical to a "
               f"single-replica reference, the decode replica's "
               f"measurement window asserted to compute zero prompt "
               f"tokens, and both pools asserted leak-free after "
               f"draining migrations; the chaos companion drops "
               f"kv.migrate transfers mid-flight and asserts "
               f"retry-to-completion with leak-free pools on both ends",
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: tiny 2-replica affinity + steal "
                         "cases with asserted A/B directions, seconds "
                         "not minutes")
    ap.add_argument("--repeats", type=int, default=3,
                    help="median-of-N repeats for wall-clock A/Bs "
                         "(token counts are deterministic; the wall "
                         "clock on this 1-core host is not)")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run(repeats=args.repeats)
