"""LM serving benchmark: continuous batching vs the legacy wave decode.

Three scenarios, all real compute on this host, emitted as one JSON
artifact (`artifacts/bench/serving_bench.json`) with stable keys so runs
are comparable across PRs:

  1. `replicas_{1,2}` — replica scaling with least-loaded request pull
     (the paper's multi-NCS protocol at LM scale).
  2. `mixed_wave` / `mixed_continuous` — mixed-length requests (prompts
     6..19 tokens, max_new_tokens drawn from {4, 64}) on one replica with
     4 decode slots.  The wave path lock-steps every wave to its slowest
     member; continuous batching refills a slot the moment its request
     finishes.  `mixed_continuous` runs the paged KV engine with a block
     pool sized <= 50% of the worst-case contiguous footprint;
     `mixed_continuous_contig` is the contiguous A/B twin.
     `continuous_speedup` (paged vs wave) and `paged_vs_contiguous`
     (tokens/s ratio at half the KV memory) are the headline numbers, with
     `kv_pool_frac` / `prefill_compiles` showing where the win comes from
     (paging + prompt-length bucketing vs per-length recompiles).
  3. `arrival` — a seeded arrival process submitted against a running
     engine (service mode): requests admitted mid-stream, the scenario a
     batch-offline API cannot express.

Each scenario reports tokens/s, TTFT p50/p99 (ms), mean TPOT (ms), slot
occupancy, prefill jit compiles, and (paged) peak KV-pool blocks and
utilization.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import (MultiReplicaEngine, Request, ServeStats,
                                  ServingEngine)
from repro.serving.sampler import greedy

from benchmarks.common import save_artifact


def _requests(cfg, n, prompt_len=12, new_tokens=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens, sampler=greedy())
            for i in range(n)]


def _mixed_requests(cfg, n=16, seed=0):
    """Alternating short/long decodes over *varied* prompt lengths: the
    stressor for both continuous batching (ragged finish times) and the
    prefill compile cache (ragged prompt shapes)."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(6, 20)))
                    .astype(np.int32),
                    max_new_tokens=4 if i % 2 else 64, sampler=greedy())
            for i in range(n)]


def _summary(stats: ServeStats) -> dict:
    ms = lambda v: round(v * 1e3, 2) if v is not None else None  # noqa: E731
    return {
        "requests": stats.requests, "tokens": stats.tokens,
        "wall_s": round(stats.wall_s, 3),
        "tokens_per_s": round(stats.tokens_per_s, 2),
        "ttft_p50_ms": ms(stats.ttft_p50_s),
        "ttft_p99_ms": ms(stats.ttft_p99_s),
        "tpot_ms": ms(stats.mean_tpot_s),
        "slot_occupancy": round(stats.slot_occupancy, 3),
        "prefills": stats.prefills, "decode_steps": stats.decode_steps,
        "prefill_compiles": stats.prefill_compiles,
        "kv_blocks_peak": stats.kv_blocks_peak,
        "kv_pool_util": (round(stats.kv_pool_util, 3)
                         if stats.kv_pool_util is not None else None),
    }


def _kv_state_bytes(eng: ServingEngine) -> int:
    """Device bytes of the engine's batched KV decode state."""
    if eng._state is None:
        eng._state = eng._init_state()
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(eng._state))


def _warmup(eng: ServingEngine, cfg) -> None:
    """Compile prefill/decode outside the timed region.  Uses a full wave
    (= batch_slots requests) so both paths hit the same jitted (slots, 1)
    decode signature before timing starts."""
    eng.serve(_requests(cfg, eng.slots, new_tokens=2, seed=99))
    eng.serve_wave(_requests(cfg, eng.slots, new_tokens=2, seed=99))


def run(verbose: bool = True) -> dict:
    cfg = arch_registry.smoke("qwen2.5-3b")
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    out = {}

    # -- scenario 1: replica scaling --------------------------------------
    for n_rep in (1, 2):
        replicas = [ServingEngine(cfg, params, max_len=24, batch_slots=4)
                    for _ in range(n_rep)]
        if n_rep == 1:
            stats = replicas[0].serve(_requests(cfg, 16))
        else:
            stats = MultiReplicaEngine(replicas).serve(_requests(cfg, 16))
        rep = tpu_serving_report(stats.tokens_per_s, chips=n_rep)
        out[f"replicas_{n_rep}"] = dict(
            _summary(stats), tokens_per_s_per_w=rep.items_per_watt)
        if verbose:
            print(f"serving x{n_rep}: {stats.tokens_per_s:.1f} tok/s  "
                  f"{rep.items_per_watt:.4f} tok/s/W  "
                  f"occ={stats.slot_occupancy:.2f}")
    out["replica_scaling_2x"] = (out["replicas_2"]["tokens_per_s"]
                                 / out["replicas_1"]["tokens_per_s"])
    out["note"] = ("this host has ONE CPU core, so two real replicas "
                   "contend for it; protocol-level replica scaling is "
                   "demonstrated with calibrated targets in fig6b (7.7x/8)")

    # -- scenario 2: mixed-length — wave vs continuous, paged vs contiguous
    slots, block = 4, 16
    max_len = 19 + 64 + 1                     # longest prompt + budget
    # paged pool sized <= 50% of the worst-case contiguous footprint
    pool_blocks = (slots * max_len) // (2 * block) - 1
    contig = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                           paged=False)
    paged = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                          paged=True, block_size=block,
                          pool_blocks=pool_blocks)
    _warmup(contig, cfg)
    _warmup(paged, cfg)
    out["mixed_wave"] = _summary(contig.serve_wave(_mixed_requests(cfg)))
    out["mixed_continuous_contig"] = _summary(
        contig.serve(_mixed_requests(cfg)))
    out["mixed_continuous"] = _summary(paged.serve(_mixed_requests(cfg)))
    out["continuous_speedup"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_wave"]["tokens_per_s"], 3)
    out["paged_vs_contiguous"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_continuous_contig"]["tokens_per_s"], 3)
    out["kv_bytes_contiguous"] = _kv_state_bytes(contig)
    out["kv_bytes_paged"] = _kv_state_bytes(paged)
    out["kv_pool_frac"] = round(out["kv_bytes_paged"]
                                / out["kv_bytes_contiguous"], 3)
    if verbose:
        for k in ("mixed_wave", "mixed_continuous_contig",
                  "mixed_continuous"):
            s = out[k]
            print(f"{k}: {s['tokens_per_s']:.1f} tok/s  "
                  f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
                  f"occ={s['slot_occupancy']}  "
                  f"compiles={s['prefill_compiles']}")
        print(f"continuous vs wave speedup: {out['continuous_speedup']:.2f}x")
        print(f"paged vs contiguous: {out['paged_vs_contiguous']:.2f}x "
              f"tok/s at {out['kv_pool_frac']:.0%} of the KV footprint "
              f"(peak util {out['mixed_continuous']['kv_pool_util']})")

    # -- scenario 3: arrival process against a running engine --------------
    eng2 = ServingEngine(cfg, params, max_len=12 + 16, batch_slots=4)
    _warmup(eng2, cfg)
    reqs = _requests(cfg, 12, new_tokens=6, seed=1)
    for i, r in enumerate(reqs):
        r.max_new_tokens = 4 if i % 2 else 16
    rng = np.random.default_rng(2)
    gaps = rng.exponential(0.01, size=len(reqs))
    done = threading.Event()
    remaining = [len(reqs)]

    def fin(_):
        remaining[0] -= 1
        if remaining[0] == 0:
            done.set()

    base = (eng2.totals.decode_steps, eng2.totals.occupancy_sum,
            eng2.prefill_compiles)
    if eng2.pool is not None:
        eng2.pool.reset_peak()
    eng2.start()
    t0 = time.monotonic()
    for r, gap in zip(reqs, gaps):
        time.sleep(gap)
        r.submitted_at = time.monotonic()
        eng2.submit(r, on_finish=fin)
    done.wait(timeout=120)
    wall = time.monotonic() - t0
    eng2.stop()
    stats = ServeStats(requests=len(reqs), wall_s=wall,
                       tokens=sum(len(r.output) for r in reqs))
    stats.decode_steps = eng2.totals.decode_steps - base[0]
    stats.occupancy_sum = eng2.totals.occupancy_sum - base[1]
    stats.prefill_compiles = eng2.prefill_compiles - base[2]
    if eng2.pool is not None:
        stats.kv_blocks_peak = eng2.pool.peak_used
        stats.kv_pool_util = eng2.pool.utilization
    stats.fill_request_metrics(reqs)
    out["arrival"] = _summary(stats)
    if verbose:
        s = out["arrival"]
        print(f"arrival: {s['tokens_per_s']:.1f} tok/s  "
              f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
              f"occ={s['slot_occupancy']}")

    save_artifact("serving_bench", out)
    return out


if __name__ == "__main__":
    run()
