"""LM serving through the offload engine: the paper's multi-device protocol
applied to its TPU-era analogue (replica groups serving token streams).

Reports tokens/s and tokens/s/W for 1 and 2 replica groups on the smoke
config (real compute on this host), demonstrating the same near-linear
replica scaling the paper shows for NCS devices.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import MultiReplicaEngine, Request, ServingEngine
from repro.serving.sampler import greedy

from benchmarks.common import save_artifact


def _requests(cfg, n, prompt_len=12, new_tokens=6):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens, sampler=greedy())
            for i in range(n)]


def run(verbose: bool = True) -> dict:
    cfg = arch_registry.smoke("qwen2.5-3b")
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    out = {}
    for n_rep in (1, 2):
        replicas = [ServingEngine(cfg, params, max_len=24, batch_slots=4)
                    for _ in range(n_rep)]
        if n_rep == 1:
            stats = replicas[0].serve(_requests(cfg, 16))
        else:
            stats = MultiReplicaEngine(replicas).serve(_requests(cfg, 16),
                                                       group_size=4)
        rep = tpu_serving_report(stats.tokens_per_s, chips=n_rep)
        out[f"replicas_{n_rep}"] = {
            "tokens": stats.tokens, "wall_s": stats.wall_s,
            "tokens_per_s": stats.tokens_per_s,
            "tokens_per_s_per_w": rep.items_per_watt,
        }
        if verbose:
            print(f"serving x{n_rep}: {stats.tokens_per_s:.1f} tok/s  "
                  f"{rep.items_per_watt:.4f} tok/s/W")
    speedup = (out["replicas_2"]["tokens_per_s"]
               / out["replicas_1"]["tokens_per_s"])
    out["replica_scaling_2x"] = speedup
    out["note"] = ("this host has ONE CPU core, so two real replicas "
                   "contend for it; protocol-level replica scaling is "
                   "demonstrated with calibrated targets in fig6b (7.7x/8)")
    if verbose:
        print(f"serving replica scaling 1->2: {speedup:.2f}x "
              f"(single-core host: contention expected; see fig6b for the "
              f"protocol scaling)")
    save_artifact("serving_bench", out)
    return out


if __name__ == "__main__":
    run()
