"""LM serving benchmark: continuous batching vs the legacy wave decode.

Three scenarios, all real compute on this host, emitted as one JSON
artifact (`artifacts/bench/serving_bench.json`) with stable keys so runs
are comparable across PRs:

  1. `replicas_{1,2}` — replica scaling with least-loaded request pull
     (the paper's multi-NCS protocol at LM scale).
  2. `mixed_wave` / `mixed_continuous` — mixed-length requests (prompts
     6..19 tokens, max_new_tokens drawn from {4, 64}) on one replica with
     4 decode slots.  The wave path lock-steps every wave to its slowest
     member; continuous batching refills a slot the moment its request
     finishes.  `mixed_continuous` runs the paged KV engine with a block
     pool sized <= 50% of the worst-case contiguous footprint;
     `mixed_continuous_contig` is the contiguous A/B twin.
     `continuous_speedup` (paged vs wave) and `paged_vs_contiguous`
     (tokens/s ratio at half the KV memory) are the headline numbers, with
     `kv_pool_frac` / `prefill_compiles` showing where the win comes from
     (paging + prompt-length bucketing vs per-length recompiles).
  3. `arrival` — a seeded arrival process submitted against a running
     engine (service mode): requests admitted mid-stream, the scenario a
     batch-offline API cannot express.
  4. `priority_fifo` / `priority_slo` — the same pressure workload (long
     low-priority decodes wedging the pool, short high-priority requests
     arriving mid-stream) served without and with SLO-aware scheduling;
     `priority_hipri_ttft_p99_speedup` (high-priority p99 TTFT, FIFO /
     SLO) and `priority_tokens_cost_frac` (aggregate tokens/s given up to
     preemption recompute) are the headline pair.
  5. `shared_prefix` / `shared_prefix_nosharing` — N requests over one
     long common prompt prefix with refcounted prefix sharing on and off;
     with sharing the pool peaks below N x prefix-blocks
     (`shared_prefix_nominal_prefix_blocks`) because every request's
     leading table entries point at one shared copy.
  6. `seeded_prefill` / `seeded_prefill_recompute` — the cache-seeded
     prefill A/B: N co-resident requests over one long common prefix,
     served with seeding on (prefill computation starts at the first
     unseeded token) and off (PR-3 behaviour: shared blocks mapped but
     every prompt token re-run into the trash block).
     `prefill_tokens_computed` vs `prefill_tokens_total` is the headline
     pair — seeded compute must drop proportionally to the shared
     fraction — with `seeded_outputs_match` asserting the greedy streams
     are identical token for token.
  7. `chunked_interleave` / `chunked_interleave_off` — a 1024-token
     prompt arriving mid-decode, prefilled in 64-token chunks interleaved
     with decode steps vs all at once; `decode_stall_p99_ms` (the p99 gap
     between consecutive decode steps) is the headline — un-chunked, the
     whole prefill shows up as one giant stall for every active decode.

Wall-clock A/Bs run median-of-3 on a warm engine (this single-core
host's clock jitters ~25%).  Each scenario reports tokens/s, TTFT
p50/p99 (ms), mean TPOT (ms), slot occupancy, prefill jit compiles,
prefill tokens computed vs total, decode-stall p99, preemptions,
prefix-shared table entries, SLO miss rate, and (paged) peak KV-pool
blocks and utilization.  The headline numbers are also written to a
repo-root `BENCH_4.json` trajectory artifact.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import (MultiReplicaEngine, Request, ServeStats,
                                  ServingEngine)
from repro.serving.sampler import greedy

from benchmarks.common import save_artifact


def _requests(cfg, n, prompt_len=12, new_tokens=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens, sampler=greedy())
            for i in range(n)]


def _mixed_requests(cfg, n=16, seed=0):
    """Alternating short/long decodes over *varied* prompt lengths: the
    stressor for both continuous batching (ragged finish times) and the
    prefill compile cache (ragged prompt shapes)."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(6, 20)))
                    .astype(np.int32),
                    max_new_tokens=4 if i % 2 else 64, sampler=greedy())
            for i in range(n)]


def _shared_prefix_requests(cfg, n=6, prefix_blocks=2, block=16, seed=4):
    """N prompts sharing a ``prefix_blocks``-block common prefix with
    distinct 8-token tails: with refcounted prefix sharing the pool holds
    ONE copy of the prefix instead of N."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size,
                          size=prefix_blocks * block).astype(np.int32)
    return [Request(i, np.concatenate(
                    [prefix, rng.integers(0, cfg.vocab_size, size=8)
                     .astype(np.int32)]),
                    max_new_tokens=4, sampler=greedy())
            for i in range(n)]


def _run_pressure(cfg, params, *, slo_aware: bool, repeats: int = 3):
    """Queue-pressure A/B arm: 8 long low-priority decodes wedge every
    slot and pool block; 4 short requests arrive mid-stream.
    ``slo_aware=True`` marks the late arrivals priority-2 with a TTFT SLO
    (they preempt); ``False`` leaves everything priority-0 (the old FIFO
    behaviour: late arrivals wait behind every queued long decode).

    The workload repeats ``repeats`` times on the same warm engine and the
    median-wall run is reported: this single-core host's wall clock is
    noisy enough (~20%) to swamp the few-percent preemption-recompute
    cost the A/B is trying to measure."""
    slots, block, low_new = 4, 16, 192
    rows = 8 + low_new - 1
    pool = slots * -(-rows // block)     # lows wedge the pool exactly
    eng = ServingEngine(cfg, params, max_len=8 + low_new + 1,
                        batch_slots=slots, paged=True, block_size=block,
                        pool_blocks=pool)
    # warm the (slots, 1) decode signature and the 16..128 prefill buckets
    # this run can hit (preemption re-prefills prompt + generated tokens)
    eng.serve(_requests(cfg, slots, prompt_len=8, new_tokens=2, seed=99))
    for n, plen in ((2, 20), (2, 33), (2, 65)):
        eng.serve(_requests(cfg, n, prompt_len=plen, new_tokens=2,
                            seed=90 + plen))
    runs = []
    for rep in range(repeats):
        rng = np.random.default_rng(3 + rep)
        lows = [Request(i, rng.integers(0, cfg.vocab_size, size=8)
                        .astype(np.int32), max_new_tokens=low_new,
                        sampler=greedy())
                for i in range(8)]
        highs = [Request(100 + i, rng.integers(0, cfg.vocab_size, size=8)
                         .astype(np.int32), max_new_tokens=4,
                         sampler=greedy(),
                         priority=2 if slo_aware else 0,
                         slo_ttft_s=0.5 if slo_aware else None)
                 for i in range(4)]
        done = threading.Event()
        remaining = [len(lows) + len(highs)]

        def fin(_, remaining=remaining, done=done):
            remaining[0] -= 1
            if remaining[0] == 0:
                done.set()

        base = eng.begin_window()
        eng.start()
        t0 = time.monotonic()
        for r in lows:
            eng.submit(r, on_finish=fin)
        time.sleep(0.1)              # lows now hold every pool block
        for r in highs:
            eng.submit(r, on_finish=fin)
        done.wait(timeout=180)
        wall = time.monotonic() - t0
        eng.stop()
        stats = eng.collect_window(base, lows + highs, wall)
        # censor a never-served request's TTFT at the window wall so a
        # timeout degrades the number instead of crashing the percentile
        ttfts = [r.ttft_s if r.ttft_s is not None else wall for r in highs]
        p99_ms = round(float(np.percentile(ttfts, 99)) * 1e3, 2)
        runs.append((wall, stats, p99_ms))
    runs.sort(key=lambda r: r[0])
    _, stats, p99_ms = runs[len(runs) // 2]
    return stats, p99_ms


def _run_seeded(cfg, params, *, seeded: bool, repeats: int = 3):
    """Cache-seeded prefill A/B arm: 6 co-resident requests over one
    64-token (4-block) common prefix with 8-token tails.  ``seeded=True``
    starts prefill computation at the first unseeded token; ``False`` is
    the PR-3 recompute baseline (shared blocks mapped, every prompt token
    re-run into the trash block).  Median-wall run of ``repeats`` on a
    warm engine; token counts are deterministic, wall clock is not."""
    n = 6
    eng = ServingEngine(cfg, params, max_len=64 + 8 + 4 + 1, batch_slots=n,
                        paged=True, block_size=16, seeded_prefill=seeded)
    mk = lambda: _shared_prefix_requests(cfg, n=n, prefix_blocks=4,  # noqa
                                         block=16, seed=21)
    eng.serve(mk())                     # warm: compiles + prefix publish
    runs = []
    for _ in range(repeats):
        reqs = mk()
        stats = eng.serve(reqs)
        runs.append((stats.wall_s, stats, [r.output for r in reqs]))
    runs.sort(key=lambda r: r[0])
    _, stats, outputs = runs[len(runs) // 2]
    return stats, outputs


def _run_chunked(cfg, params, *, chunk: int | None, repeats: int = 3):
    """Chunked-interleave A/B arm: 3 short-prompt decodes are mid-stream
    when a 1024-token prompt arrives.  With ``chunk`` set its prefill runs
    in chunk-token slices between decode steps; with ``None`` it stalls
    every active decode for the whole prefill (the stall is the window's
    ``decode_stall_p99``).  Driven synchronously through the executor
    step so arrival timing is identical across arms, and the workload
    tokens are fixed across repeats so the reported (median-wall) run is
    output-comparable between arms; median-of-``repeats`` on a warm
    engine."""
    P = 1024
    eng = ServingEngine(cfg, params, max_len=P + 16, batch_slots=4,
                        paged=True, block_size=16, prefill_chunk=chunk)
    # warm every jitted signature both arms can hit: the (4, 1) decode,
    # short-prompt buckets, and the long prompt's chunk/bucket shapes
    eng.serve(_requests(cfg, 4, prompt_len=8, new_tokens=2, seed=98))
    eng.serve(_requests(cfg, 1, prompt_len=P, new_tokens=2, seed=97))
    rng = np.random.default_rng(31)
    dec_prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
                   for _ in range(3)]
    big_prompt = rng.integers(0, cfg.vocab_size, size=P).astype(np.int32)
    runs = []
    for rep in range(repeats):
        decs = [Request(10 * rep + i, p, max_new_tokens=48,
                        sampler=greedy())
                for i, p in enumerate(dec_prompts)]
        big = Request(10 * rep + 9, big_prompt, max_new_tokens=4,
                      sampler=greedy())
        base = eng.begin_window()
        t0 = time.monotonic()
        for r in decs:
            eng.scheduler.submit(r)
        for _ in range(8):              # decodes are cruising...
            eng._step()
        eng.scheduler.submit(big)       # ...when the long prompt lands
        while eng.scheduler.has_work():
            eng._step()
        wall = time.monotonic() - t0
        stats = eng.collect_window(base, decs + [big], wall)
        runs.append((wall, stats, [r.output for r in decs + [big]]))
    runs.sort(key=lambda r: r[0])
    _, stats, outputs = runs[len(runs) // 2]
    return stats, outputs


def _summary(stats: ServeStats) -> dict:
    ms = lambda v: round(v * 1e3, 2) if v is not None else None  # noqa: E731
    return {
        "requests": stats.requests, "tokens": stats.tokens,
        "wall_s": round(stats.wall_s, 3),
        "tokens_per_s": round(stats.tokens_per_s, 2),
        "ttft_p50_ms": ms(stats.ttft_p50_s),
        "ttft_p99_ms": ms(stats.ttft_p99_s),
        "tpot_ms": ms(stats.mean_tpot_s),
        "slot_occupancy": round(stats.slot_occupancy, 3),
        "prefills": stats.prefills, "decode_steps": stats.decode_steps,
        "prefill_compiles": stats.prefill_compiles,
        "prefill_tokens_total": stats.prefill_tokens_total,
        "prefill_tokens_computed": stats.prefill_tokens_computed,
        "decode_stall_p99_ms": ms(stats.decode_stall_p99_s),
        "preemptions": stats.preemptions,
        "prefix_shared_blocks": stats.prefix_shared_blocks,
        "slo_miss_rate": (round(stats.slo_miss_rate, 3)
                          if stats.slo_miss_rate is not None else None),
        "kv_blocks_peak": stats.kv_blocks_peak,
        "kv_pool_util": (round(stats.kv_pool_util, 3)
                         if stats.kv_pool_util is not None else None),
    }


def _kv_state_bytes(eng: ServingEngine) -> int:
    """Device bytes of the engine's batched KV decode state."""
    if eng._state is None:
        eng._state = eng._init_state()
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(eng._state))


def _warmup(eng: ServingEngine, cfg) -> None:
    """Compile prefill/decode outside the timed region.  Uses a full wave
    (= batch_slots requests) so both paths hit the same jitted (slots, 1)
    decode signature before timing starts."""
    eng.serve(_requests(cfg, eng.slots, new_tokens=2, seed=99))
    eng.serve_wave(_requests(cfg, eng.slots, new_tokens=2, seed=99))


def run(verbose: bool = True) -> dict:
    cfg = arch_registry.smoke("qwen2.5-3b")
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    out = {}

    # -- scenario 1: replica scaling --------------------------------------
    for n_rep in (1, 2):
        replicas = [ServingEngine(cfg, params, max_len=24, batch_slots=4)
                    for _ in range(n_rep)]
        if n_rep == 1:
            stats = replicas[0].serve(_requests(cfg, 16))
        else:
            stats = MultiReplicaEngine(replicas).serve(_requests(cfg, 16))
        rep = tpu_serving_report(stats.tokens_per_s, chips=n_rep)
        out[f"replicas_{n_rep}"] = dict(
            _summary(stats), tokens_per_s_per_w=rep.items_per_watt)
        if verbose:
            print(f"serving x{n_rep}: {stats.tokens_per_s:.1f} tok/s  "
                  f"{rep.items_per_watt:.4f} tok/s/W  "
                  f"occ={stats.slot_occupancy:.2f}")
    out["replica_scaling_2x"] = (out["replicas_2"]["tokens_per_s"]
                                 / out["replicas_1"]["tokens_per_s"])
    out["note"] = ("this host has ONE CPU core, so two real replicas "
                   "contend for it; protocol-level replica scaling is "
                   "demonstrated with calibrated targets in fig6b (7.7x/8)")

    # -- scenario 2: mixed-length — wave vs continuous, paged vs contiguous
    slots, block = 4, 16
    max_len = 19 + 64 + 1                     # longest prompt + budget
    # paged pool sized <= 50% of the worst-case contiguous footprint
    pool_blocks = (slots * max_len) // (2 * block) - 1
    contig = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                           paged=False)
    paged = ServingEngine(cfg, params, max_len=max_len, batch_slots=slots,
                          paged=True, block_size=block,
                          pool_blocks=pool_blocks)
    _warmup(contig, cfg)
    _warmup(paged, cfg)
    out["mixed_wave"] = _summary(contig.serve_wave(_mixed_requests(cfg)))
    out["mixed_continuous_contig"] = _summary(
        contig.serve(_mixed_requests(cfg)))
    out["mixed_continuous"] = _summary(paged.serve(_mixed_requests(cfg)))
    out["continuous_speedup"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_wave"]["tokens_per_s"], 3)
    out["paged_vs_contiguous"] = round(
        out["mixed_continuous"]["tokens_per_s"]
        / out["mixed_continuous_contig"]["tokens_per_s"], 3)
    out["kv_bytes_contiguous"] = _kv_state_bytes(contig)
    out["kv_bytes_paged"] = _kv_state_bytes(paged)
    out["kv_pool_frac"] = round(out["kv_bytes_paged"]
                                / out["kv_bytes_contiguous"], 3)
    if verbose:
        for k in ("mixed_wave", "mixed_continuous_contig",
                  "mixed_continuous"):
            s = out[k]
            print(f"{k}: {s['tokens_per_s']:.1f} tok/s  "
                  f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
                  f"occ={s['slot_occupancy']}  "
                  f"compiles={s['prefill_compiles']}")
        print(f"continuous vs wave speedup: {out['continuous_speedup']:.2f}x")
        print(f"paged vs contiguous: {out['paged_vs_contiguous']:.2f}x "
              f"tok/s at {out['kv_pool_frac']:.0%} of the KV footprint "
              f"(peak util {out['mixed_continuous']['kv_pool_util']})")

    # -- scenario 3: arrival process against a running engine --------------
    eng2 = ServingEngine(cfg, params, max_len=12 + 16, batch_slots=4)
    _warmup(eng2, cfg)
    reqs = _requests(cfg, 12, new_tokens=6, seed=1)
    for i, r in enumerate(reqs):
        r.max_new_tokens = 4 if i % 2 else 16
    rng = np.random.default_rng(2)
    gaps = rng.exponential(0.01, size=len(reqs))
    done = threading.Event()
    remaining = [len(reqs)]

    def fin(_):
        remaining[0] -= 1
        if remaining[0] == 0:
            done.set()

    base = eng2.begin_window()
    eng2.start()
    t0 = time.monotonic()
    for r, gap in zip(reqs, gaps):
        time.sleep(gap)
        # scheduler.submit stamps submitted_at at true submission time
        eng2.submit(r, on_finish=fin)
    done.wait(timeout=120)
    wall = time.monotonic() - t0
    eng2.stop()
    out["arrival"] = _summary(eng2.collect_window(base, reqs, wall))
    if verbose:
        s = out["arrival"]
        print(f"arrival: {s['tokens_per_s']:.1f} tok/s  "
              f"ttft p50={s['ttft_p50_ms']}ms p99={s['ttft_p99_ms']}ms  "
              f"occ={s['slot_occupancy']}")

    # -- scenario 4: priority under pressure (SLO-aware vs FIFO) -----------
    for key, slo_aware in (("priority_fifo", False), ("priority_slo", True)):
        stats, hipri_p99_ms = _run_pressure(cfg, params, slo_aware=slo_aware)
        s = _summary(stats)
        s["hipri_ttft_p99_ms"] = hipri_p99_ms
        out[key] = s
    out["priority_hipri_ttft_p99_speedup"] = round(
        out["priority_fifo"]["hipri_ttft_p99_ms"]
        / out["priority_slo"]["hipri_ttft_p99_ms"], 3)
    out["priority_tokens_cost_frac"] = round(
        1.0 - (out["priority_slo"]["tokens_per_s"]
               / out["priority_fifo"]["tokens_per_s"]), 3)
    if verbose:
        print(f"priority: hi-pri ttft p99 "
              f"{out['priority_fifo']['hipri_ttft_p99_ms']}ms (fifo) -> "
              f"{out['priority_slo']['hipri_ttft_p99_ms']}ms (slo), "
              f"{out['priority_hipri_ttft_p99_speedup']:.1f}x better at "
              f"{out['priority_tokens_cost_frac']:.1%} tok/s cost "
              f"({out['priority_slo']['preemptions']} preemptions, "
              f"slo miss {out['priority_slo']['slo_miss_rate']})")

    # -- scenario 5: shared prompt prefix (refcounted blocks) --------------
    n_share, prefix_blocks = 6, 2
    for key, sharing in (("shared_prefix", True),
                         ("shared_prefix_nosharing", False)):
        eng = ServingEngine(cfg, params, max_len=2 * 16 + 8 + 4 + 1,
                            batch_slots=n_share, prefix_sharing=sharing)
        _warmup(eng, cfg)
        out[key] = _summary(eng.serve(_shared_prefix_requests(
            cfg, n=n_share, prefix_blocks=prefix_blocks)))
    out["shared_prefix_nominal_prefix_blocks"] = n_share * prefix_blocks
    if verbose:
        s = out["shared_prefix"]
        print(f"shared_prefix: peak {s['kv_blocks_peak']} blocks "
              f"(unshared {out['shared_prefix_nosharing']['kv_blocks_peak']},"
              f" nominal prefix demand "
              f"{out['shared_prefix_nominal_prefix_blocks']}) — "
              f"{s['prefix_shared_blocks']} table entries shared")

    # -- scenario 6: cache-seeded prefill vs full recompute ----------------
    seeded_out = {}
    for key, seeded in (("seeded_prefill", True),
                        ("seeded_prefill_recompute", False)):
        stats, seeded_out[key] = _run_seeded(cfg, params, seeded=seeded)
        out[key] = _summary(stats)
    out["seeded_outputs_match"] = (
        seeded_out["seeded_prefill"] == seeded_out["seeded_prefill_recompute"])
    out["seeded_prefill_compute_frac"] = round(
        out["seeded_prefill"]["prefill_tokens_computed"]
        / out["seeded_prefill_recompute"]["prefill_tokens_computed"], 3)
    if verbose:
        s, r = out["seeded_prefill"], out["seeded_prefill_recompute"]
        print(f"seeded_prefill: {s['prefill_tokens_computed']}"
              f"/{s['prefill_tokens_total']} prompt tokens computed vs "
              f"{r['prefill_tokens_computed']} recomputed "
              f"({out['seeded_prefill_compute_frac']:.0%} of baseline), "
              f"outputs match: {out['seeded_outputs_match']}")

    # -- scenario 7: chunked prefill interleaved with decode ---------------
    chunk_out = {}
    for key, chunk in (("chunked_interleave", 64),
                       ("chunked_interleave_off", None)):
        stats, chunk_out[key] = _run_chunked(cfg, params, chunk=chunk)
        out[key] = _summary(stats)
    out["chunked_outputs_match"] = (
        chunk_out["chunked_interleave"] == chunk_out["chunked_interleave_off"])
    out["chunked_stall_p99_improvement"] = round(
        out["chunked_interleave_off"]["decode_stall_p99_ms"]
        / out["chunked_interleave"]["decode_stall_p99_ms"], 3)
    if verbose:
        c, u = out["chunked_interleave"], out["chunked_interleave_off"]
        print(f"chunked_interleave: decode stall p99 "
              f"{u['decode_stall_p99_ms']}ms (off) -> "
              f"{c['decode_stall_p99_ms']}ms (chunk 64), "
              f"{out['chunked_stall_p99_improvement']:.1f}x better, "
              f"outputs match: {out['chunked_outputs_match']}")

    save_artifact("serving_bench", out)
    _save_bench4(out)
    return out


def _save_bench4(out: dict) -> str:
    """Repo-root trajectory artifact with this PR's headline numbers."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_4.json")
    payload = {
        "pr": 4,
        "title": "cache-seeded chunked prefill: paged prefill-attention "
                 "kernel + prefill/decode interleaving",
        "seeded_prefill_tokens_computed":
            out["seeded_prefill"]["prefill_tokens_computed"],
        "seeded_prefill_tokens_total":
            out["seeded_prefill"]["prefill_tokens_total"],
        "recompute_prefill_tokens_computed":
            out["seeded_prefill_recompute"]["prefill_tokens_computed"],
        "seeded_prefill_compute_frac": out["seeded_prefill_compute_frac"],
        "seeded_outputs_match": out["seeded_outputs_match"],
        "seeded_tokens_per_s": out["seeded_prefill"]["tokens_per_s"],
        "recompute_tokens_per_s":
            out["seeded_prefill_recompute"]["tokens_per_s"],
        "chunked_decode_stall_p99_ms":
            out["chunked_interleave"]["decode_stall_p99_ms"],
        "unchunked_decode_stall_p99_ms":
            out["chunked_interleave_off"]["decode_stall_p99_ms"],
        "chunked_stall_p99_improvement":
            out["chunked_stall_p99_improvement"],
        "chunked_outputs_match": out["chunked_outputs_match"],
        "method": "median-of-3 repeats on a warm engine (single-core "
                  "host wall clock jitters ~25%); token counts and "
                  "output equality are deterministic",
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


if __name__ == "__main__":
    run()
