"""Paper Fig 6a: inference throughput, batch=8, CPU vs GPU vs 8xVPU.

Two modes:
  * calibrated — SimTargets with the paper's measured latencies; reproduces
    the figure's numbers (77.2 / 44.0 / 74.2 img/s) up to scheduling noise.
  * host — REAL GoogLeNet inference through the same engine on this CPU
    (absolute numbers differ; the engine/protocol is identical).
"""
from __future__ import annotations

from repro.core.offload import OffloadEngine
from repro.core.power import PAPER_THROUGHPUT_8

from benchmarks.common import (SIM_ITEMS, SIM_SCALE, googlenet_cpu_target,
                               image_stream, paper_host_target,
                               paper_vpu_targets, save_artifact)


def run(verbose: bool = True) -> dict:
    out = {"paper_reference_img_s": PAPER_THROUGHPUT_8}

    # --- calibrated reproduction -------------------------------------------
    calib = {}
    with OffloadEngine(paper_vpu_targets(8)) as eng:
        _, st = eng.run(range(SIM_ITEMS))
    calib["vpu_x8"] = st.throughput * SIM_SCALE
    for kind in ("cpu", "gpu"):
        with OffloadEngine([paper_host_target(kind, batch=8)]) as eng:
            _, st = eng.run(range(SIM_ITEMS // 8))
        calib[kind] = st.throughput * 8 * SIM_SCALE
    out["calibrated_img_s"] = calib

    # --- real host inference through the same engine ------------------------
    stream = image_stream(6, batch=8)
    with OffloadEngine([googlenet_cpu_target(batch=8)]) as eng:
        _, st = eng.run([s["images"] for s in stream])
    out["host_googlenet_img_s"] = st.throughput * 8

    if verbose:
        print("fig6a  paper img/s:", PAPER_THROUGHPUT_8)
        print("fig6a  calibrated img/s:",
              {k: round(v, 1) for k, v in calib.items()})
        print("fig6a  host GoogLeNet img/s:",
              round(out["host_googlenet_img_s"], 2))
    save_artifact("fig6a_throughput", out)
    return out


if __name__ == "__main__":
    run()
