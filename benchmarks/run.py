"""Run every benchmark (one per paper figure + roofline + kernels).

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> int:
    from benchmarks import (fig6a_throughput, fig6b_scaling, fig7_error_rate,
                            fig8_throughput_watt, kernel_bench,
                            roofline_table, serving_bench)
    suites = [
        ("fig6a_throughput", fig6a_throughput.run),
        ("fig6b_scaling", fig6b_scaling.run),
        ("fig7_error_rate", fig7_error_rate.run),
        ("fig8_throughput_watt", fig8_throughput_watt.run),
        ("serving_bench", serving_bench.run),
        ("kernel_bench", kernel_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        print(f"\n=== {name} ===")
        try:
            fn()
            print(f"--- {name} OK ({time.time()-t0:.1f}s)")
        except Exception:   # noqa: BLE001
            failures += 1
            print(f"--- {name} FAILED")
            traceback.print_exc()
    print(f"\nbenchmarks: {len(suites)-failures}/{len(suites)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
