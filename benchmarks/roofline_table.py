"""Generate the 40-cell roofline table from dry-run artifacts (§Roofline)."""
from __future__ import annotations

import os

from repro.roofline.analysis import (from_record, improvement_hint,
                                     load_records, table)

from benchmarks.common import save_artifact

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(verbose: bool = True) -> dict:
    recs = load_records(ART)
    ok = [r for r in recs if r.get("status") == "OK"]
    if not ok:
        print("roofline: no dry-run artifacts yet — run "
              "`python -m repro.launch.dryrun --all --mesh both`")
        return {}
    out = {"n_cells": len(recs)}
    for mesh in ("single", "multi"):
        md = table(recs, mesh=mesh)
        out[f"table_{mesh}"] = md
        if verbose:
            print(f"\n== roofline ({mesh}-pod) ==")
            print(md)
    hints = {}
    for rec in ok:
        if rec["mesh"] != "single":
            continue
        r = from_record(rec)
        hints[f"{r.arch}|{r.shape}"] = {
            "dominant": r.dominant, "hint": improvement_hint(r),
            "roofline_fraction": r.roofline_fraction}
    out["hints"] = hints
    save_artifact("roofline_table", out)
    return out


if __name__ == "__main__":
    run()
