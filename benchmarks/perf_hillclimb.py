"""§Perf hillclimb driver: three cells, hypothesis -> change -> measure.

Cells (from the §Roofline baseline):
  A llama3-405b x train_4k    — most collective-bound (FSDP gathers + TP)
  B llama3-405b x decode_32k  — most representative of the paper's technique
                                (multi-device serving offload, reduced precision)
  C qwen3-moe-235b-a22b x train_4k — worst roofline fraction (EP dispatch)

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb
Writes artifacts/bench/perf_hillclimb.json with every iteration's roofline
terms; EXPERIMENTS.md §Perf narrates the log.
"""
import os

# the dry-run device flag, scoped to this driver exactly like dryrun.py
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json  # noqa: E402
import time  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402
from repro.roofline.hw import TPU_V5E  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench",
                   "perf_hillclimb.json")


def terms(rec):
    h = rec["hlo"]
    links = TPU_V5E.ici_link_bandwidth * TPU_V5E.ici_links
    t = {
        "compute_s": h["flops_per_device"] / TPU_V5E.peak_flops_bf16,
        "memory_s": h["bytes_per_device"] / TPU_V5E.hbm_bandwidth,
        "collective_s": h["collective_ring_bytes"] / links,
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
        "useful": rec["model"]["useful_flops_ratio"],
    }
    t["bound_s"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    t["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: t[k])
    t["roofline_frac"] = rec["model"]["model_flops_global"] / (
        rec["devices"] * TPU_V5E.peak_flops_bf16 * t["bound_s"])
    return t


def iterate(log, cell_name, arch, shape, steps):
    print(f"\n#### {cell_name}: {arch} x {shape}")
    results = []
    for label, hypothesis, overrides in steps:
        t0 = time.time()
        rec = run_cell(arch, shape, False, None, overrides=overrides,
                       verbose=False)
        if rec["status"] != "OK":
            print(f"  {label}: FAILED {rec.get('error', '')[:100]}")
            results.append({"label": label, "hypothesis": hypothesis,
                            "overrides": overrides, "status": "FAIL"})
            continue
        t = terms(rec)
        t.update({"label": label, "hypothesis": hypothesis,
                  "overrides": overrides, "status": "OK",
                  "wall_s": round(time.time() - t0, 1)})
        results.append(t)
        print(f"  {label:28s} compute {t['compute_s']:7.2f}  "
              f"mem {t['memory_s']:7.2f}  coll {t['collective_s']:7.2f}  "
              f"bound {t['bound_s']:7.2f} ({t['dominant'][:-2]})  "
              f"frac {t['roofline_frac']:.3f}  peak {t['peak_gib']:.1f}GiB")
    log[cell_name] = results
    return results


def main():
    log = {}

    iterate(log, "A_llama405_train", "llama3-405b", "train_4k", [
        ("baseline (accum=16)",
         "paper-faithful baseline: FSDP+TP+SP, full remat, chunk=1024",
         {}),
        ("accum 16->8",
         "FSDP weight all-gathers happen once per microbatch; halving "
         "microbatch count halves gather traffic (collective term ~40%+ "
         "down) at the cost of 2x saved-carry memory",
         {"accum": 8}),
        ("accum 8 + chunk 4096",
         "single-chunk attention removes inter-chunk (m,l,acc) carry "
         "traffic from the scan: memory term down, flops unchanged",
         {"accum": 8, "chunk": 4096}),
        ("accum 8 + chunk 4096 + remat dots",
         "saving dot outputs (dots_with_no_batch_dims policy) removes the "
         "recompute pass' matmuls: compute term down ~25%, memory up",
         {"accum": 8, "chunk": 4096, "remat": "dots"}),
        ("accum 4 + chunk 4096",
         "push gather amortization further: 4 microbatches; check memory "
         "headroom (carries x4 vs accum 16)",
         {"accum": 4, "chunk": 4096}),
    ])

    iterate(log, "B_llama405_decode", "llama3-405b", "decode_32k", [
        ("baseline (bf16 KV)",
         "paper-faithful reduced-precision serving: bf16 weights + bf16 "
         "sequence-sharded KV cache, LSE-merge decode",
         {}),
        ("int8 KV cache [beyond-paper]",
         "the paper shows FP16 inference is safe; int8 KV with per-(slot,"
         "head) absmax scales halves cache bytes (8.6->4.3 GiB/chip) and "
         "cache read traffic; top-1 agreement verified in tests",
         {"cache_dtype": "int8"}),
        ("int8 KV + kv replicated (ablation)",
         "REFUTATION check: without sequence-sharded KV the cache "
         "replicates across the model axis and memory explodes — confirms "
         "the LSE-merge layout is load-bearing",
         {"cache_dtype": "int8", "seq_shard_kv": False}),
    ])

    iterate(log, "C_qwen3moe_train", "qwen3-moe-235b-a22b", "train_4k", [
        ("baseline (cf=1.25, accum=8)",
         "paper-faithful baseline: EP all-to-all dispatch, capacity 1.25",
         {}),
        ("capacity 1.25->1.0",
         "dispatch/expert buffers and a2a payloads scale linearly with "
         "capacity_factor: expect ~20% off collective+memory terms at the "
         "cost of more dropped tokens under imbalance",
         {"capacity_factor": 1.0}),
        ("cf 1.0 + accum 8->16",
         "per-microbatch dispatch buffers halve with token count per "
         "microbatch: live memory down; total a2a bytes unchanged",
         {"capacity_factor": 1.0, "accum": 16}),
        ("cf 1.0 + accum 16 + chunk 4096",
         "attention chunk carries removed (same as cell A)",
         {"capacity_factor": 1.0, "accum": 16, "chunk": 4096}),
    ])

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(log, f, indent=1)
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
