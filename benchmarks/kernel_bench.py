"""Kernel micro-benchmarks: correctness deltas + analytic VMEM/MXU roofline
per block configuration (no TPU on this host, so the report is structural:
working-set bytes vs VMEM, FLOPs per HBM byte vs the v5e ridge point).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.roofline.hw import TPU_V5E

from benchmarks.common import save_artifact

RIDGE = TPU_V5E.peak_flops_bf16 / TPU_V5E.hbm_bandwidth   # flops/byte


def _gemm_stats(m, n, k, bm, bn, bk, dtype_bytes=2):
    vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
    flops = 2 * m * n * k
    hbm = (m * k + k * n) * dtype_bytes * (n // bn if False else 1) + \
        m * n * dtype_bytes
    # per-tile K-stream model: x tile read n/bn times, y tile read m/bm times
    hbm = (m * k * (n // bn) + k * n * (m // bm)) * dtype_bytes \
        + m * n * dtype_bytes
    return {"vmem_bytes": vmem, "flops": flops, "hbm_bytes": hbm,
            "intensity": flops / hbm, "ridge": RIDGE,
            "compute_bound": flops / hbm > RIDGE}


def run(verbose: bool = True) -> dict:
    out = {}
    # correctness spot checks (interpret mode)
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (256, 256), jnp.bfloat16)
    y = jax.random.normal(ks[1], (256, 256), jnp.bfloat16)
    ref = matmul_ref(x, y).astype(jnp.float32)
    err = float(jnp.abs(
        matmul(x, y, bm=128, bn=128, bk=128, interpret=True).astype(jnp.float32)
        - ref).max())
    out["matmul_err"] = err / float(jnp.abs(ref).max())   # relative (bf16)

    q = jax.random.normal(ks[2], (1, 256, 4, 64))
    k = jax.random.normal(ks[3], (1, 256, 2, 64))
    v = jax.random.normal(ks[4], (1, 256, 2, 64))
    out["flash_err"] = float(jnp.abs(
        flash_attention(q, k, v, bq=128, bkv=128, interpret=True)
        - flash_attention_ref(q, k, v)).max())

    qd = jax.random.normal(ks[5], (2, 4, 64))
    lengths = jnp.array([100, 200], jnp.int32)
    out["decode_err"] = float(jnp.abs(
        decode_attention(qd, k, v, lengths, bkv=128, interpret=True)
        - decode_attention_ref(qd, k, v, lengths)).max())

    ld = -jax.nn.softplus(jax.random.normal(ks[6], (1, 256, 4)))
    lg = 0.1 * jax.random.normal(ks[7], (1, 256, 4))
    qs = jax.random.normal(ks[2], (1, 256, 4, 16))
    ks_ = jax.random.normal(ks[3], (1, 256, 4, 16))
    vs = jax.random.normal(ks[4], (1, 256, 4, 16))
    out["ssm_err"] = float(jnp.abs(
        ssm_scan(qs, ks_, vs, ld, lg, chunk=64, interpret=True)
        - ssm_scan_ref(qs, ks_, vs, ld, lg, chunk=64)).max())

    # structural roofline for the production GEMM tiling
    out["gemm_512"] = _gemm_stats(8192, 8192, 8192, 512, 512, 512)
    out["gemm_256"] = _gemm_stats(8192, 8192, 8192, 256, 256, 256)
    if verbose:
        print("kernels errs:", {k: v for k, v in out.items()
                                if k.endswith("_err")})
        print("gemm tiling 512:", {k: round(v, 2) if isinstance(v, float)
                                   else v for k, v in out["gemm_512"].items()})
    save_artifact("kernel_bench", out)
    assert max(v for k, v in out.items() if k.endswith("_err")) < 1e-2
    return out


if __name__ == "__main__":
    run()
