"""Kernel micro-benchmarks: correctness deltas + analytic VMEM/MXU roofline
per block configuration (no TPU on this host, so the report is structural:
working-set bytes vs VMEM, FLOPs per HBM byte vs the v5e ridge point).

``--smoke`` runs only the Pallas-vs-oracle correctness checks (interpret
mode on CPU, compiled on TPU) and exits non-zero on any mismatch — the
tier-1 CI gate against kernel regressions.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.conv2d.ops        # noqa: F401  (register_kernel)
import repro.kernels.decode_attention.ops  # noqa: F401
import repro.kernels.flash_attention.ops   # noqa: F401
import repro.kernels.matmul.ops        # noqa: F401
import repro.kernels.prefill_attention.ops  # noqa: F401
import repro.kernels.ssm_scan.ops      # noqa: F401
from repro.kernels.conv2d.kernel import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.decode_attention.kernel import (decode_attention,
                                                   paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.dispatch import kernel_table
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.prefill_attention.kernel import paged_prefill_attention
from repro.kernels.prefill_attention.ref import paged_prefill_attention_ref
from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.roofline.hw import TPU_V5E

from benchmarks.common import save_artifact

RIDGE = TPU_V5E.peak_flops_bf16 / TPU_V5E.hbm_bandwidth   # flops/byte

# registered kernel name -> the err key(s) its smoke cases produce (a tuple
# lists every gated shape family); smoke() fails if a kernel is registered
# in the dispatch table without a case here
COVERAGE = {
    "matmul": "matmul_err",
    "flash_attention": "flash_err",
    "decode_attention": "decode_err",
    "paged_decode_attention": "paged_decode_err",
    "paged_prefill_attention": ("paged_prefill_err",
                                "paged_prefill_verify_err",
                                "paged_prefill_verify_int8_err"),
    "ssm_scan": "ssm_err",
    "conv2d": "conv2d_err",
}


def _gemm_stats(m, n, k, bm, bn, bk, dtype_bytes=2):
    vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
    flops = 2 * m * n * k
    hbm = (m * k + k * n) * dtype_bytes * (n // bn if False else 1) + \
        m * n * dtype_bytes
    # per-tile K-stream model: x tile read n/bn times, y tile read m/bm times
    hbm = (m * k * (n // bn) + k * n * (m // bm)) * dtype_bytes \
        + m * n * dtype_bytes
    return {"vmem_bytes": vmem, "flops": flops, "hbm_bytes": hbm,
            "intensity": flops / hbm, "ridge": RIDGE,
            "compute_bound": flops / hbm > RIDGE}


def _kernel_errs(interpret: bool = True) -> dict:
    """Pallas-vs-oracle max abs error for every registered kernel family."""
    out = {}
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (256, 256), jnp.bfloat16)
    y = jax.random.normal(ks[1], (256, 256), jnp.bfloat16)
    ref = matmul_ref(x, y).astype(jnp.float32)
    err = float(jnp.abs(
        matmul(x, y, bm=128, bn=128, bk=128, interpret=interpret).astype(jnp.float32)
        - ref).max())
    out["matmul_err"] = err / float(jnp.abs(ref).max())   # relative (bf16)

    q = jax.random.normal(ks[2], (1, 256, 4, 64))
    k = jax.random.normal(ks[3], (1, 256, 2, 64))
    v = jax.random.normal(ks[4], (1, 256, 2, 64))
    out["flash_err"] = float(jnp.abs(
        flash_attention(q, k, v, bq=128, bkv=128, interpret=interpret)
        - flash_attention_ref(q, k, v)).max())

    qd = jax.random.normal(ks[5], (2, 4, 64))
    lengths = jnp.array([100, 200], jnp.int32)
    out["decode_err"] = float(jnp.abs(
        decode_attention(qd, k, v, lengths, bkv=128, interpret=interpret)
        - decode_attention_ref(qd, k, v, lengths)).max())

    # paged decode: pool + shuffled block tables + ragged lengths
    bs, mb = 16, 4
    kp = jax.random.normal(ks[6], (1 + 2 * mb, bs, 2, 64))
    vp = jax.random.normal(ks[7], (1 + 2 * mb, bs, 2, 64))
    rng = np.random.default_rng(0)
    tables = jnp.asarray(1 + rng.permutation(2 * mb).reshape(2, mb)
                         .astype(np.int32))
    plens = jnp.array([37, 64], jnp.int32)
    out["paged_decode_err"] = float(jnp.abs(
        paged_decode_attention(qd, kp, vp, tables, plens,
                               interpret=interpret)
        - paged_decode_attention_ref(qd, kp, vp, tables, plens)).max())
    from repro.models.transformer import quantize_kv
    kq, ksc = quantize_kv(kp)
    vq, vsc = quantize_kv(vp)
    out["paged_decode_int8_err"] = float(jnp.abs(
        paged_decode_attention(qd, kq, vq, tables, plens, k_scale=ksc,
                               v_scale=vsc, interpret=interpret)
        - paged_decode_attention_ref(qd, kq, vq, tables, plens,
                                     k_scale=ksc, v_scale=vsc)).max())

    # paged prefill: a multi-row chunk offset into seeded pool KV (causal
    # against absolute positions), same pool/tables as the decode case
    qc = jax.random.normal(ks[5], (2, 8, 4, 64))
    q_start = jnp.array([21, 48], jnp.int32)      # seeded rows before chunk
    clens = q_start + 8
    out["paged_prefill_err"] = float(jnp.abs(
        paged_prefill_attention(qc, kp, vp, tables, q_start, clens,
                                interpret=interpret)
        - paged_prefill_attention_ref(qc, kp, vp, tables, q_start,
                                      clens)).max())
    out["paged_prefill_int8_err"] = float(jnp.abs(
        paged_prefill_attention(qc, kq, vq, tables, q_start, clens,
                                k_scale=ksc, v_scale=vsc,
                                interpret=interpret)
        - paged_prefill_attention_ref(qc, kq, vq, tables, q_start, clens,
                                      k_scale=ksc, v_scale=vsc)).max())

    # verify-shaped paged prefill (speculative decoding): a short k+1-token
    # chunk starting mid-sequence against a short visible block table —
    # the shape `_verify_step` issues every speculative round
    qv = jax.random.normal(ks[5], (2, 4, 4, 64))
    vtables = tables[:, :2]                       # mb=2: 32 visible rows
    vq_start = jnp.array([9, 27], jnp.int32)      # mid-block / near-edge
    vlens = vq_start + 4
    out["paged_prefill_verify_err"] = float(jnp.abs(
        paged_prefill_attention(qv, kp, vp, vtables, vq_start, vlens,
                                interpret=interpret)
        - paged_prefill_attention_ref(qv, kp, vp, vtables, vq_start,
                                      vlens)).max())
    out["paged_prefill_verify_int8_err"] = float(jnp.abs(
        paged_prefill_attention(qv, kq, vq, vtables, vq_start, vlens,
                                k_scale=ksc, v_scale=vsc,
                                interpret=interpret)
        - paged_prefill_attention_ref(qv, kq, vq, vtables, vq_start, vlens,
                                      k_scale=ksc, v_scale=vsc)).max())

    ld = -jax.nn.softplus(jax.random.normal(ks[6], (1, 256, 4)))
    lg = 0.1 * jax.random.normal(ks[7], (1, 256, 4))
    qs = jax.random.normal(ks[2], (1, 256, 4, 16))
    ks_ = jax.random.normal(ks[3], (1, 256, 4, 16))
    vs = jax.random.normal(ks[4], (1, 256, 4, 16))
    out["ssm_err"] = float(jnp.abs(
        ssm_scan(qs, ks_, vs, ld, lg, chunk=64, interpret=interpret)
        - ssm_scan_ref(qs, ks_, vs, ld, lg, chunk=64)).max())

    xc = jax.random.normal(ks[0], (1, 12, 12, 4))
    wc = jax.random.normal(ks[1], (3, 3, 4, 8)) * 0.1
    bc = jax.random.normal(ks[2], (8,)) * 0.1
    out["conv2d_err"] = float(jnp.abs(
        conv2d(xc, wc, bc, stride=1, bc=8, interpret=interpret)
        - conv2d_ref(xc, wc, bc, stride=1)).max())
    return out


def smoke(verbose: bool = True) -> dict:
    """CI gate: every kernel in the dispatch table vs its oracle;
    interpret-mode fallback off-TPU so the check runs on CPU runners too.
    A kernel registered without a COVERAGE case fails the gate outright."""
    uncovered = set(kernel_table()) - set(COVERAGE)
    if uncovered:
        print(f"FAIL: registered kernels without a smoke case: "
              f"{sorted(uncovered)}", file=sys.stderr)
        sys.exit(1)
    interpret = jax.default_backend() != "tpu"
    errs = _kernel_errs(interpret=interpret)
    needed = {key for v in COVERAGE.values()
              for key in (v if isinstance(v, tuple) else (v,))}
    stale = needed - set(errs)
    if stale:       # a COVERAGE entry whose case was deleted/renamed
        print(f"FAIL: smoke cases missing from _kernel_errs: "
              f"{sorted(stale)}", file=sys.stderr)
        sys.exit(1)
    if verbose:
        mode = "interpret" if interpret else "compiled"
        print(f"kernel smoke ({mode}):",
              {k: f"{v:.2e}" for k, v in errs.items()})
    bad = {k: v for k, v in errs.items() if not v < 1e-2}
    if bad:
        print("FAIL: kernel regressions:", bad, file=sys.stderr)
        sys.exit(1)
    if verbose:
        print("kernel smoke PASS")
    return errs


def run(verbose: bool = True) -> dict:
    out = _kernel_errs(interpret=True)
    # structural roofline for the production GEMM tiling
    out["gemm_512"] = _gemm_stats(8192, 8192, 8192, 512, 512, 512)
    out["gemm_256"] = _gemm_stats(8192, 8192, 8192, 256, 256, 256)
    if verbose:
        print("kernels errs:", {k: v for k, v in out.items()
                                if k.endswith("_err")})
        print("gemm tiling 512:", {k: round(v, 2) if isinstance(v, float)
                                   else v for k, v in out["gemm_512"].items()})
    save_artifact("kernel_bench", out)
    assert max(v for k, v in out.items() if k.endswith("_err")) < 1e-2
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="correctness-only CI gate (no artifact)")
    if ap.parse_args().smoke:
        smoke()
    else:
        run()
