"""Data pipeline: deterministic synthetic sources + host-side prefetch.

The real ILSVRC-2012 dataset and pretrained Caffe weights are not available
offline, so sources are synthetic-but-deterministic (seeded); the paper's
quantities we reproduce (scaling, precision deltas, throughput/W) do not
depend on the actual pixels.  The pipeline shape matches production: an
iterator of host batches, a background prefetch thread, and per-host
sharding of the global batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np


class SyntheticTokens:
    """LM token stream: (tokens, labels) with labels = next token."""

    def __init__(self, cfg, batch: int, seq_len: int, *, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        # a deterministic, slightly-structured stream (zipfian-ish ids)
        z = self.rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = (z % self.cfg.vocab_size).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.m_rope:
            pos = np.broadcast_to(np.arange(self.seq_len, dtype=np.int32),
                                  (self.batch, self.seq_len))
            out["positions"] = np.broadcast_to(pos, (3, *pos.shape)).copy()
        if self.cfg.family == "audio":
            out["frames"] = self.rng.standard_normal(
                (self.batch, self.cfg.encdec.num_encoder_frames,
                 self.cfg.d_model), dtype=np.float32)
        return out


class SyntheticImages:
    """ILSVRC-like image stream for GoogLeNet: (images, labels).

    Images are seeded Gaussian blobs around class-dependent means so that a
    *deterministic* mapping image->class exists (the FP16-vs-FP32 comparison
    needs the same inputs on both precisions, not real photos).
    """

    def __init__(self, num_classes: int = 1000, batch: int = 8,
                 size: int = 224, *, seed: int = 0):
        self.num_classes = num_classes
        self.batch = batch
        self.size = size
        self.rng = np.random.default_rng(seed)

    def sample(self, n: int) -> dict:
        labels = self.rng.integers(0, self.num_classes, size=n).astype(np.int32)
        base = (labels[:, None, None, None].astype(np.float32)
                / self.num_classes - 0.5)
        noise = self.rng.standard_normal(
            (n, self.size, self.size, 3), dtype=np.float32)
        return {"images": base + 0.5 * noise, "labels": labels}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self.sample(self.batch)


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded queue)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()


def shard_batch(batch: dict, mesh, rules) -> dict:
    """Place a host batch onto the mesh with the policy's batch sharding."""
    from jax.sharding import NamedSharding
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim >= 3 and v.shape[0] == 3:
            axes = (None, "batch", "seq")
        elif v.ndim == 1:
            axes = ("batch",)
        elif v.ndim == 2:
            axes = ("batch", "seq")
        else:
            axes = ("batch",) + (None,) * (v.ndim - 1)
        spec = rules.spec([a for a in axes])
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
