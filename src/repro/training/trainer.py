"""Training loop with checkpoint/auto-resume, fault recovery, and elastic
re-meshing hooks.

The loop is deliberately boring: jitted step, periodic async checkpoint,
fault schedule checked every step.  On a 'crash' fault it restores the last
committed checkpoint (losing at most `ckpt_every-1` steps); on
'device_loss' it additionally asks `distributed.elastic` for a shrunken
mesh and re-shards state before continuing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import Checkpointer
from repro.distributed.fault import FaultSchedule, Heartbeat, SimulatedFault
from repro.models.registry import fns_for
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.training.train_step import make_train_step


@dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg, data_iter: Iterator[dict], tc: TrainerConfig,
                 *, optimizer: Optimizer | None = None,
                 fault_schedule: FaultSchedule | None = None,
                 accum: int | None = None,
                 on_device_loss: Callable[[], None] | None = None):
        self.cfg = cfg
        self.tc = tc
        self.data_iter = data_iter
        self.fns = fns_for(cfg)
        self.optimizer = optimizer or make_optimizer(cfg)
        self.faults = fault_schedule or FaultSchedule()
        self.heartbeat = Heartbeat()
        self.ckpt = Checkpointer(tc.ckpt_dir, keep=tc.keep,
                                 async_save=tc.async_save)
        self.on_device_loss = on_device_loss
        self._step_fn = jax.jit(
            make_train_step(cfg, self.optimizer, accum=accum))
        self.step = 0
        self.params = None
        self.opt_state = None
        self.history: list[dict] = []

    # -- state ------------------------------------------------------------------

    def init_state(self) -> None:
        key = jax.random.PRNGKey(self.tc.seed)
        self.params = self.fns.init(self.cfg, key)
        self.opt_state = self.optimizer.init(self.params)
        self.step = 0

    def try_resume(self) -> bool:
        if self.params is None:
            self.init_state()
        like = {"params": self.params, "opt": self.opt_state,
                "step": jnp.zeros((), jnp.int32)}
        res = self.ckpt.restore_latest(like)
        if res is None:
            return False
        step, tree = res
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(tree["step"])
        return True

    def save(self) -> None:
        self.ckpt.save(self.step, {
            "params": self.params, "opt": self.opt_state,
            "step": jnp.asarray(self.step, jnp.int32)})

    # -- loop -------------------------------------------------------------------

    def train(self) -> list[dict]:
        if self.params is None and not self.try_resume():
            self.init_state()
        while self.step < self.tc.num_steps:
            try:
                self._one_step()
            except SimulatedFault as f:
                self._recover(f)
        self.ckpt.wait()
        return self.history

    def _one_step(self) -> None:
        self.faults.check(self.step)
        batch = next(self.data_iter)
        t0 = time.monotonic()
        self.params, self.opt_state, metrics = self._step_fn(
            self.params, self.opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step"] = self.step
        metrics["step_time_s"] = time.monotonic() - t0
        self.heartbeat.beat()
        self.history.append(metrics)
        self.step += 1
        if self.step % self.tc.ckpt_every == 0:
            self.save()

    def _recover(self, fault: SimulatedFault) -> None:
        """Restore last checkpoint; on device loss also re-mesh."""
        if fault.kind == "device_loss" and self.on_device_loss is not None:
            self.on_device_loss()
        resumed = self.try_resume()
        if not resumed:
            self.init_state()
        self.history.append({"step": self.step, "event": fault.kind,
                             "resumed_from": self.step if resumed else 0})
