"""Train-step factory: remat'd forward/backward, gradient accumulation via
`lax.scan` over microbatches, optional cross-pod gradient compression, then
the optimizer update.

Gradient accumulation is the compute/communication-overlap lever: with the
parameters FSDP-sharded, XLA's latency-hiding scheduler overlaps microbatch
k's reduce-scatter with microbatch k+1's compute — and it bounds live
activation / MoE-dispatch memory for the biggest cells.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.registry import fns_for
from repro.training.losses import classification_cross_entropy, lm_cross_entropy

_METRIC_KEYS = ("loss", "nll", "accuracy", "aux_loss")


def make_loss_fn(cfg, *, chunk: int = 4096) -> Callable:
    fns = fns_for(cfg)

    def loss_fn(params, batch):
        if cfg.family == "cnn":
            logits, aux = fns.forward(cfg, params, batch)
            loss, m = classification_cross_entropy(logits, batch["labels"])
            metrics = {"loss": loss, "nll": loss, "accuracy": m["accuracy"],
                       "aux_loss": aux}
        else:
            logits, aux = fns.forward(cfg, params, batch, chunk=chunk)
            loss, m = lm_cross_entropy(logits, batch["labels"])
            metrics = {"loss": loss, "nll": m["nll"],
                       "accuracy": m["accuracy"], "aux_loss": aux}
        return loss + aux, metrics

    return loss_fn


def _split_microbatches(batch: dict, accum: int) -> dict:
    """(B, ...) -> (A, B/A, ...) along the batch axis of every input."""
    def split(x):
        if x.ndim >= 3 and x.shape[0] == 3:   # M-RoPE positions (3, B, S)
            return x.reshape(3, accum, x.shape[1] // accum,
                             *x.shape[2:]).swapaxes(0, 1)
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg, optimizer, *, accum: int | None = None,
                    chunk: int = 4096,
                    grad_transform: Callable | None = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``grad_transform`` hooks post-accumulation gradients (e.g. int8
    compression on the cross-pod axis — see repro.optim.compression).
    """
    loss_fn = make_loss_fn(cfg, chunk=chunk)
    accum = accum if accum is not None else cfg.accum_steps
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    # bf16-param models (e.g. llama3-405b pure-bf16 training) accumulate in
    # bf16 to halve gradient-buffer memory; fp32 otherwise.
    acc_dt = (jnp.bfloat16 if cfg.param_dtype == "bfloat16"
              else jnp.float32)

    def _finish(grads, metrics, params, opt_state):
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = optimizer.update(grads, opt_state,
                                                            params)
        return new_params, new_opt, {**metrics, **opt_metrics}

    if accum <= 1:
        def train_step(params, opt_state, batch):
            (_, metrics), grads = grad_fn(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(acc_dt), grads)
            return _finish(grads, metrics, params, opt_state)
        return train_step

    def train_step(params, opt_state, batch):
        micro = _split_microbatches(batch, accum)

        def body(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), g_acc, grads)
            m_acc = {k: m_acc[k] + metrics[k] for k in _METRIC_KEYS}
            return (g_acc, m_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        m0 = {k: jnp.zeros((), jnp.float32) for k in _METRIC_KEYS}
        (grads, msum), _ = jax.lax.scan(body, (g0, m0), micro)
        grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        metrics = {k: v / accum for k, v in msum.items()}
        return _finish(grads, metrics, params, opt_state)

    return train_step
