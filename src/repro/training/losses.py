"""Loss functions.

Cross-entropy avoids materializing one-hot targets: the label logit is
picked with an iota-compare-and-reduce that XLA fuses, so peak memory is the
(vocab-sharded) logits themselves.  A small z-loss regularizer keeps the
softmax normalizer bounded (standard at production scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_cross_entropy(logits: jax.Array, labels: jax.Array,
                     mask: jax.Array | None = None, *,
                     z_loss: float = 1e-4):
    """logits: (B, S, V) fp32; labels: (B, S) int32. Returns (loss, metrics)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                      # (B, S)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                     axis=-1)                                    # (B, S)
    nll = lse - picked
    zl = z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((nll + zl) * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, axis=-1) == labels) * mask) / denom
    return loss, {"nll": jnp.sum(nll * mask) / denom, "accuracy": acc}


def classification_cross_entropy(logits: jax.Array, labels: jax.Array):
    """logits: (B, C) fp32; labels: (B,) int32 (GoogLeNet training)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - picked)
    acc = jnp.mean(jnp.argmax(logits, axis=-1) == labels)
    return loss, {"accuracy": acc}
