"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab.  [arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchAssignment, ModelConfig, full_attention_skips

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, norm_eps=1e-5,
    # Pure-bf16 training (PaLM/T5-style): bf16 master + Adafactor's factored
    # fp32 statistics.  fp32 master + Adam state for 405B params would need
    # ~19 GB/chip on a 256-chip v5e pod (16 GB HBM) — see DESIGN.md.
    optimizer="adafactor", accum_steps=16, param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="llama3-405b-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, accum_steps=1)

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
