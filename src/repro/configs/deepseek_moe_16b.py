"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16)
d_ff_expert=1408 vocab=102400, MoE 64 routed top-6 + 2 shared experts,
fine-grained, first layer dense (d_ff=10944).  [arXiv:2401.06066; hf]"""
from repro.configs.base import (ArchAssignment, ModelConfig, MoEConfig,
                                full_attention_skips)

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    rope_theta=10_000.0, norm_eps=1e-6,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, d_ff_shared=1408,
                  first_k_dense=1, d_ff_dense=10944,
                  norm_topk_prob=False),
    accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="deepseek-moe-16b-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=32, vocab_size=256, head_dim=16, accum_steps=1,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  num_shared_experts=2, d_ff_shared=32,
                  first_k_dense=1, d_ff_dense=128,
                  norm_topk_prob=False, capacity_factor=4.0))

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
