"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchAssignment, ModelConfig, full_attention_skips

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
    norm_eps=1e-6, accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-3b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, accum_steps=1)

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
