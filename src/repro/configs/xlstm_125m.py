"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304 — interleaved
sLSTM + mLSTM blocks (block i is sLSTM when i % 4 == 1), no separate FFN
(projection factors live inside the blocks).  Recurrent O(1) state, so
long_500k RUNS.  [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchAssignment, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    xlstm=XLSTMConfig(slstm_every=4, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, conv1d_kernel=4),
    norm_eps=1e-6, subquadratic=True, tie_embeddings=True, accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="xlstm-125m-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=4, vocab_size=256, head_dim=16, accum_steps=1,
    xlstm=XLSTMConfig(slstm_every=4, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, conv1d_kernel=4))

ASSIGNMENT = ArchAssignment(model=CONFIG)   # all four shapes run
