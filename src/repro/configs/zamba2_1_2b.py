"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + weight-shared attention
blocks.  Sub-quadratic (SSM state is O(1) in seq), so long_500k RUNS.
[arXiv:2411.15242; hf]"""
from repro.configs.base import (ArchAssignment, ModelConfig, SSMConfig)

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    shared_attn_every=6,      # 6 full segments + 2 tail mamba layers
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=128),
    rope_theta=10_000.0, norm_eps=1e-5, subquadratic=True, accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke", num_layers=5, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
    shared_attn_every=2, accum_steps=1,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk_size=32))

ASSIGNMENT = ArchAssignment(model=CONFIG)   # all four shapes run
