"""googlenet [cnn] — the paper's own evaluation model (BVLC GoogLeNet,
Inception-v1, ILSVRC-2012, input 224x224, 1000 classes).  Not part of the
assigned 40 LM cells; exercised by the paper-reproduction benchmarks
(Figs. 6-8) through the NCSw-style offload engine."""
from repro.configs.base import ArchAssignment, ModelConfig

CONFIG = ModelConfig(
    name="googlenet", family="cnn",
    num_layers=9,                 # inception modules
    d_model=1024,                 # final feature width
    num_heads=1, num_kv_heads=1, d_ff=0,
    vocab_size=1000,              # ILSVRC classes
    param_dtype="float32", compute_dtype="float32",
)

# FP16 inference config (the paper's VPU precision)
CONFIG_FP16 = CONFIG.replace(name="googlenet-fp16", compute_dtype="float16")

SMOKE = CONFIG.replace(name="googlenet-smoke")   # same graph, 64x64 inputs

ASSIGNMENT = ArchAssignment(model=CONFIG, shapes=())
