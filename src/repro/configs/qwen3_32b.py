"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA, no QKV bias.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchAssignment, ModelConfig, full_attention_skips

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="qwen3-32b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, accum_steps=1)

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
