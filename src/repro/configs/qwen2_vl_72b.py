"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE (temporal/height/width streams), dynamic resolution.
The vision frontend is a STUB per the assignment: ``input_specs`` provides
the 3-stream M-RoPE position ids; patch tokens embed via the vocabulary.
[arXiv:2409.12191; hf]"""
from repro.configs.base import ArchAssignment, ModelConfig, full_attention_skips

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    qkv_bias=True, m_rope=True, m_rope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, norm_eps=1e-6,
    optimizer="adafactor", accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="qwen2-vl-72b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=32,
    m_rope_sections=(4, 6, 6), accum_steps=1)

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
