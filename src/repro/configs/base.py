"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` plus a set of
:class:`ShapeConfig` entries (the assigned input shapes).  Configs are plain
frozen dataclasses so they hash, print, and diff cleanly; nothing here touches
jax device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard-style capacity dispatch + EP)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    # Layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeekMoE).
    first_k_dense: int = 0
    d_ff_dense: int = 0
    capacity_factor: float = 1.25
    # Normalize the top-k router probabilities to sum to one (Qwen3-MoE /
    # DeepSeek style).
    norm_topk_prob: bool = True
    router_aux_loss_weight: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) settings."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM settings: interleaved mLSTM / sLSTM blocks."""

    slstm_every: int = 4          # block i is sLSTM when i % slstm_every == 1
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Whisper-style) settings; the modality frontend is a
    STUB — ``input_specs`` provides precomputed frame embeddings."""

    num_encoder_layers: int = 24
    num_encoder_frames: int = 1500   # 30s of audio after the conv stem


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | moe | vlm | ssm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention options ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    m_rope: bool = False             # Qwen2-VL multimodal 3D RoPE
    m_rope_sections: tuple[int, ...] = (16, 24, 24)
    parallel_block: bool = False     # Cohere-style parallel attn+FFN residual
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0          # 0 -> disabled
    # --- block pattern (hybrid archs) ---
    # dense/moe archs: all layers identical.  zamba2: mamba backbone with a
    # shared attention block every `shared_attn_every` layers.
    shared_attn_every: int = 0
    # --- sub-configs ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    encdec: EncDecConfig | None = None
    # --- embeddings / norms ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    use_layernorm: bool = False      # LayerNorm (whisper/cohere) vs RMSNorm
    final_logit_softcap: float = 0.0
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- training-time knobs ---
    remat: str = "full"              # none | full | offloadable-dots
    optimizer: str = "adamw"         # adamw | adafactor
    # gradient-accumulation microbatches for the train_4k cell (keeps the
    # global batch while bounding live activation/dispatch memory)
    accum_steps: int = 1
    # sub-quadratic attention available (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell.

    ``kind`` selects which step function gets lowered:
      * ``train``    -> ``train_step``   (tokens + labels, full fwd/bwd/update)
      * ``prefill``  -> ``prefill_step`` (tokens -> logits + KV cache)
      * ``decode``   -> ``serve_step``   (1 new token against seq_len KV/state)
    """

    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int
    # number of grad-accumulation microbatches (train only; 1 = disabled)
    accum: int = 1

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES: tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME: Mapping[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ArchAssignment:
    """An architecture together with its assigned shape cells and notes about
    shape applicability (see DESIGN.md §Arch-applicability)."""

    model: ModelConfig
    shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    skipped: Mapping[str, str] = field(default_factory=dict)

    def runnable_shapes(self) -> tuple[ShapeConfig, ...]:
        return tuple(SHAPES_BY_NAME[s] for s in self.shapes if s not in self.skipped)


def full_attention_skips() -> Mapping[str, str]:
    return {
        "long_500k": (
            "pure full-attention architecture: 524k-token context requires "
            "sub-quadratic attention per the assignment; skipped and noted in "
            "DESIGN.md §Arch-applicability"
        )
    }
