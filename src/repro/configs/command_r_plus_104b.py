"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, Cohere parallel attn+FFN block,
LayerNorm.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchAssignment, ModelConfig, full_attention_skips

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, head_dim=128,
    qkv_bias=False, rope_theta=75_000_000.0, tie_embeddings=True,
    parallel_block=True, use_layernorm=True, norm_eps=1e-5,
    optimizer="adafactor", accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="command-r-plus-104b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, accum_steps=1)

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
