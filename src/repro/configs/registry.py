"""Registry of all assigned architectures (+ the paper's GoogLeNet)."""
from __future__ import annotations

from typing import Mapping

from repro.configs import (command_r_plus_104b, deepseek_moe_16b, googlenet,
                           llama3_405b, qwen2_5_3b, qwen2_vl_72b, qwen3_32b,
                           qwen3_moe_235b_a22b, whisper_medium, xlstm_125m,
                           zamba2_1_2b)
from repro.configs.base import ArchAssignment, ModelConfig

_MODULES = {
    "qwen2.5-3b": qwen2_5_3b,
    "command-r-plus-104b": command_r_plus_104b,
    "qwen3-32b": qwen3_32b,
    "llama3-405b": llama3_405b,
    "zamba2-1.2b": zamba2_1_2b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "xlstm-125m": xlstm_125m,
    "whisper-medium": whisper_medium,
}

ASSIGNED: Mapping[str, ArchAssignment] = {
    name: mod.ASSIGNMENT for name, mod in _MODULES.items()
}

SMOKE: Mapping[str, ModelConfig] = {
    name: mod.SMOKE for name, mod in _MODULES.items()
}

GOOGLENET = googlenet.CONFIG
GOOGLENET_FP16 = googlenet.CONFIG_FP16

ARCH_IDS = tuple(_MODULES)


def get(arch: str) -> ArchAssignment:
    if arch == "googlenet":
        return googlenet.ASSIGNMENT
    return ASSIGNED[arch]


def config(arch: str) -> ModelConfig:
    return get(arch).model


def smoke(arch: str) -> ModelConfig:
    if arch == "googlenet":
        return googlenet.SMOKE
    return SMOKE[arch]
