"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

`input_specs(cfg, shape)` returns the *data* arguments of the step function
selected by ``shape.kind`` (train/prefill/decode); the dry-run combines them
with abstract params/optimizer-state from the model table.  Nothing here
allocates device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import shape_dtype
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import fns_for


def _lm_batch(cfg: ModelConfig, B: int, S: int, *, labels: bool):
    d = {"tokens": shape_dtype((B, S), "int32")}
    if labels:
        d["labels"] = shape_dtype((B, S), "int32")
    if cfg.m_rope:
        d["positions"] = shape_dtype((3, B, S), "int32")
    if cfg.family == "audio":
        d["frames"] = shape_dtype(
            (B, cfg.encdec.num_encoder_frames, cfg.d_model), "bfloat16")
    return d


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_dtype: str = "bfloat16"):
    """Returns (batch_specs, extra) where extra holds decode-state specs."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "cnn":
        d = {"images": shape_dtype((B, 224, 224, 3), "float32")}
        if shape.kind == "train":
            d["labels"] = shape_dtype((B,), "int32")
        return d, None
    if shape.kind == "train":
        return _lm_batch(cfg, B, S, labels=True), None
    if shape.kind == "prefill":
        return _lm_batch(cfg, B, S, labels=False), None
    if shape.kind == "decode":
        fns = fns_for(cfg)
        state = jax.eval_shape(
            lambda: fns.init_decode_state(cfg, B, S, cache_dtype))
        tokens = shape_dtype((B, 1), "int32")
        return {"tokens": tokens}, state
    raise ValueError(shape.kind)


def abstract_params(cfg: ModelConfig):
    fns = fns_for(cfg)
    return jax.eval_shape(lambda: fns.init(cfg, jax.random.PRNGKey(0)))
