"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
d_ff_expert=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import (ArchAssignment, ModelConfig, MoEConfig,
                                full_attention_skips)

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                  norm_topk_prob=True),
    optimizer="adafactor", accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="qwen3-moe-235b-a22b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16, accum_steps=1,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  norm_topk_prob=True, capacity_factor=4.0))

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
