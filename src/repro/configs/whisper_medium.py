"""whisper-medium [audio] — 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — encoder-decoder; the conv/log-mel frontend is a STUB
(``input_specs`` provides precomputed frame embeddings (B, 1500, 1024)).
Decoder shapes (decode_32k) run: enc-dec is not encoder-only.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import (ArchAssignment, EncDecConfig, ModelConfig,
                                full_attention_skips)

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    qkv_bias=True, use_layernorm=True, norm_eps=1e-5,
    encdec=EncDecConfig(num_encoder_layers=24, num_encoder_frames=1500),
    accum_steps=8,
)

SMOKE = CONFIG.replace(
    name="whisper-medium-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16, accum_steps=1,
    encdec=EncDecConfig(num_encoder_layers=2, num_encoder_frames=32))

ASSIGNMENT = ArchAssignment(model=CONFIG, skipped=full_attention_skips())
