"""Zamba2-style hybrid: Mamba-2 backbone with a weight-SHARED attention
block applied every ``shared_attn_every`` layers.

Structure (L layers, e = shared_attn_every):
  [e mamba layers -> shared attn+MLP block] x (L // e)  +  (L % e) mamba tail

The shared block's weights exist ONCE; each application gets its own KV
cache.  Its input is proj(concat(hidden, embedding)) as in Zamba2 (per-
application LoRA adapters are omitted — noted in DESIGN.md).

Because the SSM state is O(1) in sequence length, this arch runs the
``long_500k`` cell: decode state = per-layer Mamba states + one KV cache per
shared-block application.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import dtype_of
from repro.distributed.sharding import constrain
from repro.models.layers import attention as A
from repro.models.layers.embedding import embed, embedding_table, logits as lm_logits
from repro.models.layers.mlp import swiglu, swiglu_table
from repro.models.layers.module import init_table, stack_table, weight
from repro.models.layers.norms import apply_norm, norm_table, rmsnorm
from repro.models.layers import ssm as S


class HybridState(NamedTuple):
    """Decode state: stacked Mamba states + per-application KV caches."""
    conv_seg: jax.Array    # (n_seg, e, B, K-1, ch)
    ssm_seg: jax.Array     # (n_seg, e, B, H, N, P)
    conv_tail: jax.Array   # (tail, B, K-1, ch)
    ssm_tail: jax.Array    # (tail, B, H, N, P)
    kv_k: jax.Array        # (n_seg, B, S, Kh, D)
    kv_v: jax.Array
    length: jax.Array      # (B,)


def _segments(cfg) -> tuple[int, int, int]:
    e = cfg.shared_attn_every
    n_seg = cfg.num_layers // e
    tail = cfg.num_layers - n_seg * e
    return n_seg, e, tail


def mamba_layer_table(cfg):
    return {"norm": norm_table(cfg), "mamba": S.mamba_table(cfg)}


def shared_block_table(cfg):
    return {
        "in_proj": weight((2 * cfg.d_model, cfg.d_model), ("embed", None)),
        "ln1": norm_table(cfg),
        "attn": A.attention_table(cfg),
        "ln2": norm_table(cfg),
        "mlp": swiglu_table(cfg.d_model, cfg.d_ff),
    }


def lm_table(cfg):
    n_seg, e, tail = _segments(cfg)
    t = {
        "embed": embedding_table(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "seg_blocks": stack_table(stack_table(mamba_layer_table(cfg), e), n_seg),
        "shared": shared_block_table(cfg),
        "ln_f": norm_table(cfg),
    }
    if tail:
        t["tail_blocks"] = stack_table(mamba_layer_table(cfg), tail)
    return t


def init(cfg, key: jax.Array):
    return init_table(key, lm_table(cfg), cfg.param_dtype)


def _mamba_residual(cfg, p, x, state=None, step=False, want_state=False):
    h = apply_norm(cfg, p["norm"], x)
    h = constrain(h, "batch", "seq", "embed_act")   # gather for the conv/SSD
    if step:
        out, new_state = S.mamba_step(cfg, p["mamba"], h, state)
        return x + out, new_state
    if want_state:
        out, new_state = S.mamba_forward(cfg, p["mamba"], h, state,
                                         return_state=True)
        return constrain(x + out, "batch", "seq_sp", "embed_act"), new_state
    out = S.mamba_forward(cfg, p["mamba"], h)
    return constrain(x + out, "batch", "seq_sp", "embed_act"), None


def _shared_attn(cfg, p, x, e0, positions, *, cache_k=None, cache_v=None,
                 kv_len=None, chunk=1024):
    """Apply the shared attention+MLP block. Returns (x, new_k, new_v)."""
    z = jnp.concatenate([x, e0], axis=-1)
    z = jnp.einsum("...c,cd->...d", z, p["in_proj"].astype(x.dtype))
    h = apply_norm(cfg, p["ln1"], z)
    if cache_k is None:
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        attn = A.chunked_attention(q, k, v, causal=True,
                                   q_positions=positions,
                                   kv_positions=positions, chunk=chunk)
        nk, nv = k, v
    else:
        from repro.distributed.collectives import seq_sharded_decode_attention
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        attn, nk, nv = seq_sharded_decode_attention(
            q, cache_k, cache_v, k, v, kv_len, chunk=chunk)
    x = x + A.attn_output(cfg, p["attn"], attn)
    h2 = apply_norm(cfg, p["ln2"], x)
    x = x + swiglu(p["mlp"], h2)
    return constrain(x, "batch", "seq_sp", "embed_act"), nk, nv


def _forward_core(cfg, params, tokens, positions, *, remat,
                  state: HybridState | None = None, collect=False,
                  chunk=1024):
    """Shared by train forward / prefill / decode(S==1 via step=False? no —
    decode uses `decode_step`).  Returns (x, new_state_or_None)."""
    compute_dt = dtype_of(cfg.compute_dtype)
    n_seg, e, tail = _segments(cfg)
    x = embed(params["embed"], tokens, compute_dt)
    e0 = x
    shared_p = params["shared"]

    def seg_body(carry, seg):
        h = carry
        p_seg = seg

        def layer_body(hh, p_layer):
            hh, st = _mamba_residual(cfg, p_layer, hh,
                                     want_state=collect)
            return hh, st

        h, states = jax.lax.scan(layer_body, h, p_seg)
        h, nk, nv = _shared_attn(cfg, shared_p, h, e0, positions, chunk=chunk)
        ys = (states, nk, nv) if collect else None
        return h, ys

    if remat and cfg.remat != "none":
        seg_body = jax.checkpoint(
            seg_body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)

    x, seg_ys = jax.lax.scan(seg_body, x, params["seg_blocks"])

    tail_states = None
    if tail:
        def tail_body(hh, p_layer):
            hh, st = _mamba_residual(cfg, p_layer, hh, want_state=collect)
            return hh, st
        x, tail_states = jax.lax.scan(tail_body, x, params["tail_blocks"])

    x = apply_norm(cfg, params["ln_f"], x)

    new_state = None
    if collect:
        states, ks, vs = seg_ys
        B = tokens.shape[0]
        new_state = HybridState(
            conv_seg=states.conv, ssm_seg=states.ssm,
            conv_tail=(tail_states.conv if tail else
                       jnp.zeros((0,) + states.conv.shape[2:], states.conv.dtype)),
            ssm_tail=(tail_states.ssm if tail else
                      jnp.zeros((0,) + states.ssm.shape[2:], states.ssm.dtype)),
            kv_k=ks, kv_v=vs,
            length=jnp.full((B,), tokens.shape[1], jnp.int32))
    return x, new_state


def forward(cfg, params, tokens, positions=None, *, remat=True, chunk=1024):
    if positions is None:
        B, Sq = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x, _ = _forward_core(cfg, params, tokens, positions, remat=remat,
                         chunk=chunk)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg, jnp.zeros((), jnp.float32)


def prefill(cfg, params, tokens, positions=None, *, cache_dtype="bfloat16",
            max_len: int | None = None, chunk=1024):
    """Prefill; KV caches sized to ``max_len`` (defaults to S)."""
    B, Sq = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x, st = _forward_core(cfg, params, tokens, positions, remat=False,
                          collect=True, chunk=chunk)
    cdt = dtype_of(cache_dtype)
    max_len = max_len or Sq
    def grow(c):
        if max_len == Sq:
            return c.astype(cdt)
        padded = jnp.zeros(c.shape[:2] + (max_len,) + c.shape[3:], cdt)
        return padded.at[:, :, :Sq].set(c.astype(cdt))
    st = st._replace(kv_k=grow(st.kv_k), kv_v=grow(st.kv_v))
    lg = lm_logits(params["embed"], x[:, -1:], cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], st


def decode_step(cfg, params, tokens, state: HybridState, *, chunk=2048):
    """tokens: (B, 1). One step through the whole stack."""
    compute_dt = dtype_of(cfg.compute_dtype)
    n_seg, e, tail = _segments(cfg)
    x = embed(params["embed"], tokens, compute_dt)
    e0 = x
    positions = state.length[:, None]
    shared_p = params["shared"]

    def seg_body(carry, seg):
        h = carry
        p_seg, conv, ssm, ck, cv = seg

        def layer_body(hh, layer):
            p_layer, cst, sst = layer
            hh, nst = _mamba_residual(cfg, p_layer, hh,
                                      state=S.MambaState(cst, sst), step=True)
            return hh, nst

        h, nstates = jax.lax.scan(layer_body, h, (p_seg, conv, ssm))
        h, nk, nv = _shared_attn(cfg, shared_p, h, e0, positions,
                                 cache_k=ck, cache_v=cv,
                                 kv_len=state.length, chunk=chunk)
        return h, (nstates, nk, nv)

    x, (nstates, ks, vs) = jax.lax.scan(
        seg_body, x,
        (params["seg_blocks"], state.conv_seg, state.ssm_seg,
         state.kv_k, state.kv_v))

    nconv_t, nssm_t = state.conv_tail, state.ssm_tail
    if tail:
        def tail_body(hh, layer):
            p_layer, cst, sst = layer
            hh, nst = _mamba_residual(cfg, p_layer, hh,
                                      state=S.MambaState(cst, sst), step=True)
            return hh, nst
        x, tstates = jax.lax.scan(tail_body, x,
                                  (params["tail_blocks"], state.conv_tail,
                                   state.ssm_tail))
        nconv_t, nssm_t = tstates.conv, tstates.ssm

    x = apply_norm(cfg, params["ln_f"], x)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    new_state = HybridState(
        conv_seg=nstates.conv, ssm_seg=nstates.ssm,
        conv_tail=nconv_t, ssm_tail=nssm_t,
        kv_k=ks, kv_v=vs, length=state.length + 1)
    return lg[:, 0], new_state


def init_decode_state(cfg, batch: int, max_len: int,
                      cache_dtype="bfloat16") -> HybridState:
    n_seg, e, tail = _segments(cfg)
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    ch = d_in + 2 * s.d_state
    cdt = dtype_of(cache_dtype)
    hd = cfg.resolved_head_dim
    return HybridState(
        conv_seg=jnp.zeros((n_seg, e, batch, s.d_conv - 1, ch), cdt),
        ssm_seg=jnp.zeros((n_seg, e, batch, h, s.d_state, s.head_dim),
                          jnp.float32),
        conv_tail=jnp.zeros((tail, batch, s.d_conv - 1, ch), cdt),
        ssm_tail=jnp.zeros((tail, batch, h, s.d_state, s.head_dim),
                           jnp.float32),
        kv_k=jnp.zeros((n_seg, batch, max_len, cfg.num_kv_heads, hd), cdt),
        kv_v=jnp.zeros((n_seg, batch, max_len, cfg.num_kv_heads, hd), cdt),
        length=jnp.zeros((batch,), jnp.int32))
