"""xLSTM LM: interleaved mLSTM / sLSTM residual blocks (unrolled stack —
the model family is small, so per-block HLO is cheap and the heterogeneous
pattern needs no scan gymnastics).

Decode state is O(1) in sequence length, so this arch runs ``long_500k``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common import dtype_of, split_keys
from repro.models.layers.embedding import embed, embedding_table, logits as lm_logits
from repro.models.layers.module import init_table
from repro.models.layers.norms import apply_norm, norm_table
from repro.models.layers import xlstm as X


def _is_slstm(cfg, i: int) -> bool:
    return i % cfg.xlstm.slstm_every == 1


def lm_table(cfg):
    blocks = []
    for i in range(cfg.num_layers):
        core = X.slstm_table(cfg) if _is_slstm(cfg, i) else X.mlstm_table(cfg)
        blocks.append({"norm": norm_table(cfg), "core": core})
    return {
        "embed": embedding_table(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "blocks": blocks,
        "ln_f": norm_table(cfg),
    }


def init(cfg, key: jax.Array):
    return init_table(key, lm_table(cfg), cfg.param_dtype)


def _apply(cfg, params, tokens, *, states=None, step=False, collect=False):
    x = embed(params["embed"], tokens, dtype_of(cfg.compute_dtype))
    new_states = []
    for i, bp in enumerate(params["blocks"]):
        h = apply_norm(cfg, bp["norm"], x)
        st = None if states is None else states[i]
        if _is_slstm(cfg, i):
            if step or collect:
                out, nst = X.slstm_forward(cfg, bp["core"], h, st,
                                           return_state=True)
            else:
                out, nst = X.slstm_forward(cfg, bp["core"], h, st), None
        else:
            if step:
                out, nst = X.mlstm_step(cfg, bp["core"], h, st)
            elif collect:
                out, nst = X.mlstm_forward(cfg, bp["core"], h, st,
                                           return_state=True)
            else:
                out, nst = X.mlstm_forward(cfg, bp["core"], h, st), None
        x = x + out
        new_states.append(nst)
    x = apply_norm(cfg, params["ln_f"], x)
    return x, new_states


def forward(cfg, params, tokens, positions=None, *, remat=True, chunk=1024):
    del positions, remat, chunk
    x, _ = _apply(cfg, params, tokens)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg, jnp.zeros((), jnp.float32)


def prefill(cfg, params, tokens, positions=None, *, cache_dtype="bfloat16",
            max_len=None, chunk=1024):
    del positions, cache_dtype, max_len, chunk
    B = tokens.shape[0]
    x, states = _apply(cfg, params, tokens, collect=True)
    lg = lm_logits(params["embed"], x[:, -1:], cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], {"states": states,
                      "length": jnp.full((B,), tokens.shape[1], jnp.int32)}


def decode_step(cfg, params, tokens, state, *, chunk=2048):
    del chunk
    x, states = _apply(cfg, params, tokens, states=state["states"], step=True)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], {"states": states, "length": state["length"] + 1}


def init_decode_state(cfg, batch: int, max_len: int, cache_dtype="bfloat16"):
    del max_len, cache_dtype
    states: list[Any] = []
    for i in range(cfg.num_layers):
        if _is_slstm(cfg, i):
            states.append(X.slstm_init_state(cfg, batch))
        else:
            states.append(X.mlstm_init_state(cfg, batch))
    return {"states": states,
            "length": jnp.zeros((batch,), jnp.int32)}
