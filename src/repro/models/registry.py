"""Uniform model API over all backbone families.

Every family exposes the same five functions so the training loop, serving
engine, and dry-run never branch on architecture:

  init(cfg, key)                          -> params
  forward(cfg, params, batch)             -> (logits, aux_loss)   # train
  prefill(cfg, params, batch, max_len)    -> (last_logits, decode_state)
  decode(cfg, params, tokens, state)      -> (logits, decode_state)
  init_decode_state(cfg, batch, max_len)  -> decode_state pytree

``batch`` is a dict: tokens/labels (+ positions for M-RoPE VLMs, + frames
for the stubbed audio frontend).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.models import encdec, googlenet, hybrid, recurrent, transformer


@dataclass(frozen=True)
class ModelFns:
    family: str
    init: Callable[..., Any]
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_decode_state: Callable[..., Any]
    table: Callable[..., Any] = None   # cfg -> ParamDef table (for sharding)
    # paged-KV support (None = family serves from contiguous caches only):
    #   init_paged_state(cfg, num_blocks, block_size, batch, max_blocks,
    #                    dtype) -> paged decode-state pytree
    #   scatter_prefill(state, dense_batch1_cache, block_ids) -> state
    #   prefill_paged(cfg, params, batch, state, write_ids, table,
    #                 q_start, kv_len, last_idx) -> (logits, state) —
    #     one prompt chunk written directly into pool blocks, attending
    #     over already-seeded blocks (cache-seeded chunked prefill)
    #   verify_paged(cfg, params, tokens, state, table, q_start, kv_len)
    #     -> ((B, C, V) logits, state) — speculative-decode verify: score
    #     k+1 candidate tokens per slot in one pass, row-scattering their
    #     KV through the (provisionally grown) block tables
    init_paged_state: Callable[..., Any] = None
    scatter_prefill: Callable[..., Any] = None
    prefill_paged: Callable[..., Any] = None
    verify_paged: Callable[..., Any] = None


# --- decoder-only transformers (dense / moe / vlm) -------------------------

def _tf_forward(cfg, params, batch, *, remat=True, chunk=1024):
    return transformer.forward(cfg, params, batch["tokens"],
                               batch.get("positions"), remat=remat,
                               chunk=chunk)


def _tf_prefill(cfg, params, batch, max_len=None, chunk=1024):
    return transformer.prefill(cfg, params, batch["tokens"],
                               batch.get("positions"), max_len=max_len,
                               chunk=chunk, last_pos=batch.get("last_pos"))


def _tf_decode(cfg, params, tokens, state, chunk=2048):
    return transformer.decode_step(cfg, params, tokens, state, chunk=chunk)


def _tf_prefill_paged(cfg, params, tokens, state, write_ids, table, *,
                      q_start, kv_len, last_idx, chunk=1024):
    return transformer.prefill_paged(cfg, params, tokens, state, write_ids,
                                     table, q_start=q_start, kv_len=kv_len,
                                     last_idx=last_idx, chunk=chunk)


def _tf_verify_paged(cfg, params, tokens, state, table, *, q_start, kv_len,
                     chunk=1024):
    return transformer.verify_paged(cfg, params, tokens, state, table,
                                    q_start=q_start, kv_len=kv_len,
                                    chunk=chunk)


def _tf_state(cfg, batch, max_len, cache_dtype="bfloat16"):
    return transformer.make_cache(cfg, batch, max_len, cache_dtype,
                                  length=jnp.full((batch,), max_len - 1,
                                                  jnp.int32))


TRANSFORMER_FNS = ModelFns("dense", transformer.init, _tf_forward,
                           _tf_prefill, _tf_decode, _tf_state,
                           table=transformer.lm_table,
                           init_paged_state=transformer.make_paged_cache,
                           scatter_prefill=transformer.scatter_prefill_blocks,
                           prefill_paged=_tf_prefill_paged,
                           verify_paged=_tf_verify_paged)


# --- hybrid (zamba2) --------------------------------------------------------

def _hy_forward(cfg, params, batch, *, remat=True, chunk=1024):
    return hybrid.forward(cfg, params, batch["tokens"], remat=remat,
                          chunk=chunk)


def _hy_prefill(cfg, params, batch, max_len=None, chunk=1024):
    return hybrid.prefill(cfg, params, batch["tokens"], max_len=max_len,
                          chunk=chunk)


def _hy_state(cfg, batch, max_len, cache_dtype="bfloat16"):
    st = hybrid.init_decode_state(cfg, batch, max_len, cache_dtype)
    return st._replace(length=jnp.full((batch,), max_len - 1, jnp.int32))


HYBRID_FNS = ModelFns("hybrid", hybrid.init, _hy_forward, _hy_prefill,
                      hybrid.decode_step, _hy_state, table=hybrid.lm_table)


# --- recurrent (xlstm) ------------------------------------------------------

def _rc_forward(cfg, params, batch, *, remat=True, chunk=1024):
    del remat, chunk
    return recurrent.forward(cfg, params, batch["tokens"])


def _rc_prefill(cfg, params, batch, max_len=None, chunk=1024):
    return recurrent.prefill(cfg, params, batch["tokens"], max_len=max_len)


def _rc_state(cfg, batch, max_len, cache_dtype="bfloat16"):
    st = recurrent.init_decode_state(cfg, batch, max_len, cache_dtype)
    st["length"] = jnp.full((batch,), max_len - 1, jnp.int32)
    return st


RECURRENT_FNS = ModelFns("ssm", recurrent.init, _rc_forward, _rc_prefill,
                         recurrent.decode_step, _rc_state,
                         table=recurrent.lm_table)


# --- encoder-decoder (whisper) ----------------------------------------------

def _ed_forward(cfg, params, batch, *, remat=True, chunk=1024):
    return encdec.forward(cfg, params, batch["tokens"], batch["frames"],
                          remat=remat, chunk=chunk)


def _ed_prefill(cfg, params, batch, max_len=None, chunk=1024):
    return encdec.prefill(cfg, params, batch["tokens"], batch["frames"],
                          max_len=max_len, chunk=chunk)


def _ed_state(cfg, batch, max_len, cache_dtype="bfloat16"):
    st = encdec.init_decode_state(cfg, batch, max_len, cache_dtype)
    return st._replace(length=jnp.full((batch,), max_len - 1, jnp.int32))


ENCDEC_FNS = ModelFns("audio", encdec.init, _ed_forward, _ed_prefill,
                      encdec.decode_step, _ed_state, table=encdec.lm_table)


# --- cnn (googlenet, the paper's model) -------------------------------------

def _gn_forward(cfg, params, batch, *, remat=True, chunk=1024):
    del remat, chunk
    return googlenet.forward(cfg, params, batch["images"]), \
        jnp.zeros((), jnp.float32)


GOOGLENET_FNS = ModelFns("cnn", googlenet.init, _gn_forward,
                         None, None, None, table=googlenet.model_table)


_BY_FAMILY: Mapping[str, ModelFns] = {
    "dense": TRANSFORMER_FNS,
    "moe": TRANSFORMER_FNS,
    "vlm": TRANSFORMER_FNS,
    "hybrid": HYBRID_FNS,
    "ssm": RECURRENT_FNS,
    "audio": ENCDEC_FNS,
    "cnn": GOOGLENET_FNS,
}


def fns_for(cfg) -> ModelFns:
    return _BY_FAMILY[cfg.family]
