"""Decoder-only transformer LM (dense, MoE, parallel-block, M-RoPE variants).

Homogeneous layer stacks are `lax.scan`-ed over stacked parameters so HLO
size is O(1) in depth (llama3-405b's 126 layers compile as one body).
Heterogeneous prefixes (DeepSeekMoE's first-k dense layers) are unrolled.

Entry points:
  * ``forward``       — full-sequence logits (training).
  * ``prefill``       — logits at the last position + filled KV cache.
  * ``prefill_paged`` — one prompt chunk written *directly* into paged
    pool blocks (no dense bucket cache + scatter round-trip), attending
    over already-seeded blocks, so shared prefixes and resumed histories
    are never recomputed.
  * ``decode_step``   — one token against a KV cache (serving).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common import dtype_of, split_keys
from repro.distributed.sharding import constrain
from repro.models.layers import attention as A
from repro.models.layers import moe as MOE
from repro.models.layers.embedding import embed, embedding_table, logits as lm_logits
from repro.models.layers.mlp import swiglu, swiglu_table
from repro.models.layers.module import init_table, stack_table
from repro.models.layers.norms import apply_norm, norm_table


class KVCache(NamedTuple):
    """Stacked per-layer KV cache. k/v: (L, B, S, K, D); length: (B,)."""
    k: jax.Array
    v: jax.Array
    length: jax.Array

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


class QuantKVCache(NamedTuple):
    """int8 KV cache [beyond-paper]: values quantized per (slot, kv-head)
    with absmax scales — halves cache HBM footprint and read traffic vs
    bf16 (the paper's FP16-is-safe finding pushed one step further).
    k/v: (L, B, S, K, D) int8; k_scale/v_scale: (L, B, S, K) f32."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    length: jax.Array

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


class PagedKVCache(NamedTuple):
    """Paged KV cache: one global pool of fixed-size blocks shared by every
    decode slot, indexed through per-slot block tables (vLLM-style).

    k/v: (L, N_blocks, block_size, K, D) — block 0 is the trash block that
    retired slots write into; block_tables: (B, max_blocks) physical block
    id per logical block, 0 where unassigned; length: (B,) valid KV rows.
    """
    k: jax.Array
    v: jax.Array
    block_tables: jax.Array
    length: jax.Array

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    @property
    def max_len(self) -> int:
        """Max addressable rows per sequence (table width x block size)."""
        return self.block_tables.shape[1] * self.k.shape[2]


class QuantPagedKVCache(NamedTuple):
    """int8 variant of :class:`PagedKVCache`: pools are int8 with absmax
    scales per (block, row, kv-head).  k/v: (L, N, bs, K, D) int8;
    k_scale/v_scale: (L, N, bs, K) f32."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    block_tables: jax.Array
    length: jax.Array

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    @property
    def max_len(self) -> int:
        return self.block_tables.shape[1] * self.k.shape[2]


def quantize_kv(x: jax.Array):
    """x: (..., D) -> (int8 (..., D), scale (...,) f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def make_cache(cfg, batch: int, max_len: int, dtype="bfloat16",
               num_layers: int | None = None,
               length: jax.Array | None = None):
    L = num_layers if num_layers is not None else cfg.num_layers
    hd = cfg.resolved_head_dim
    shape = (L, batch, max_len, cfg.num_kv_heads, hd)
    ln = jnp.zeros((batch,), jnp.int32) if length is None else length
    if dtype == "int8":
        return QuantKVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(shape[:-1], jnp.float32),
            v_scale=jnp.zeros(shape[:-1], jnp.float32), length=ln)
    dt = dtype_of(dtype)
    return KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt), length=ln)


def make_paged_cache(cfg, num_blocks: int, block_size: int, batch: int,
                     max_blocks: int, dtype="bfloat16",
                     num_layers: int | None = None):
    """Paged cache sized to ``num_blocks`` pool blocks (incl. trash block 0)
    with ``batch`` block tables of ``max_blocks`` entries each."""
    L = num_layers if num_layers is not None else cfg.num_layers
    hd = cfg.resolved_head_dim
    shape = (L, num_blocks, block_size, cfg.num_kv_heads, hd)
    tables = jnp.zeros((batch, max_blocks), jnp.int32)
    ln = jnp.zeros((batch,), jnp.int32)
    if dtype == "int8":
        return QuantPagedKVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(shape[:-1], jnp.float32),
            v_scale=jnp.zeros(shape[:-1], jnp.float32),
            block_tables=tables, length=ln)
    dt = dtype_of(dtype)
    return PagedKVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                        block_tables=tables, length=ln)


def scatter_prefill_blocks(cache, dense: KVCache, ids: jax.Array):
    """Write a batch-1 dense prefill cache into pool blocks ``ids``.

    dense.k/v: (L, 1, S, K, D) with S a multiple of the pool block size;
    ids: (S // block_size,) physical block ids in logical order (entries
    past the prompt's blocks point at the trash block 0, so bucket padding
    rows land in trash).  Returns the cache with the pools updated.
    """
    L, N, bs, K, D = cache.k.shape
    S = dense.k.shape[2]
    nb = S // bs
    kb = dense.k[:, 0].reshape(L, nb, bs, K, D)
    vb = dense.v[:, 0].reshape(L, nb, bs, K, D)
    if isinstance(cache, QuantPagedKVCache):
        kq, ksc = quantize_kv(kb)
        vq, vsc = quantize_kv(vb)
        return cache._replace(
            k=cache.k.at[:, ids].set(kq), v=cache.v.at[:, ids].set(vq),
            k_scale=cache.k_scale.at[:, ids].set(ksc),
            v_scale=cache.v_scale.at[:, ids].set(vsc))
    return cache._replace(k=cache.k.at[:, ids].set(kb.astype(cache.k.dtype)),
                          v=cache.v.at[:, ids].set(vb.astype(cache.v.dtype)))


# ---------------------------------------------------------------------------
# parameter tables
# ---------------------------------------------------------------------------

def _ffn_table(cfg):
    """Dense FFN or MoE table for one block."""
    if cfg.moe is None:
        return {"mlp": swiglu_table(cfg.d_model, cfg.d_ff)}
    m = cfg.moe
    t = {"moe": MOE.moe_table(cfg.d_model, m.num_experts, m.d_ff_expert)}
    if m.num_shared_experts:
        t["shared"] = swiglu_table(cfg.d_model,
                                   m.num_shared_experts * m.d_ff_shared)
    return t


def block_table(cfg, *, dense_ffn: bool = False):
    t = {"ln1": norm_table(cfg), "attn": A.attention_table(cfg)}
    if dense_ffn:
        ffn = {"mlp": swiglu_table(cfg.d_model,
                                   (cfg.moe.d_ff_dense or cfg.d_ff)
                                   if cfg.moe else cfg.d_ff)}
    else:
        ffn = _ffn_table(cfg)
    t.update(ffn)
    if not cfg.parallel_block:
        t["ln2"] = norm_table(cfg)
    return t


def lm_table(cfg):
    m = cfg.moe
    first_k = m.first_k_dense if m else 0
    t = {
        "embed": embedding_table(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "blocks": stack_table(block_table(cfg), cfg.num_layers - first_k),
        "ln_f": norm_table(cfg),
    }
    if first_k:
        t["dense_blocks"] = [block_table(cfg, dense_ffn=True)
                             for _ in range(first_k)]
    return t


def init(cfg, key: jax.Array):
    return init_table(key, lm_table(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _ffn_apply(cfg, p, h):
    """FFN half of a block. Returns (out, aux_loss)."""
    if cfg.moe is None or "moe" not in p:
        return swiglu(p["mlp"], h), jnp.zeros((), jnp.float32)
    m = cfg.moe
    idx, prob, aux = MOE.route(m, p["moe"], h)
    out = MOE.moe_apply(m, p["moe"], h, idx, prob)
    if m.num_shared_experts:
        out = out + swiglu(p["shared"], h)
    return out, aux


def _paged_attend(cfg, q, k_new, v_new, pool_k, pool_v, scales,
                  block_tables, length, chunk):
    """Paged decode attention for one layer: write the new KV row into the
    block-table-addressed pool slot, then attend over live blocks only.

    q/k_new/v_new: (B, 1, H|K, D); pool_k/pool_v: (N, bs, K, D) this
    layer's slice of the global pool; block_tables: (B, max_blocks);
    length: (B,) rows already valid (the new row is written at ``length``).
    Retired slots have all-zero tables, so their writes land in the trash
    block and never corrupt blocks reused by live requests.
    """
    from repro.kernels.decode_attention.ops import paged_decode_attention
    N, bs, K, D = pool_k.shape
    B = q.shape[0]
    mb = block_tables.shape[1]
    bi = jnp.clip(length // bs, 0, mb - 1)
    bt = block_tables[jnp.arange(B), bi]            # physical block per seq
    off = length % bs
    row_k, row_v = k_new[:, 0], v_new[:, 0]
    if scales is not None:
        k_scale, v_scale = scales
        kq, ks = quantize_kv(row_k)
        vq, vs = quantize_kv(row_v)
        nk = pool_k.at[bt, off].set(kq)
        nv = pool_v.at[bt, off].set(vq)
        nks = k_scale.at[bt, off].set(ks)
        nvs = v_scale.at[bt, off].set(vs)
        out = paged_decode_attention(
            q[:, 0], nk, nv, block_tables, length + 1,
            k_scale=nks, v_scale=nvs, softcap=cfg.attn_logit_softcap,
            chunk=chunk)
        return out[:, None], (nk, nv, nks, nvs)
    nk = pool_k.at[bt, off].set(row_k.astype(pool_k.dtype))
    nv = pool_v.at[bt, off].set(row_v.astype(pool_v.dtype))
    out = paged_decode_attention(q[:, 0], nk, nv, block_tables, length + 1,
                                 softcap=cfg.attn_logit_softcap, chunk=chunk)
    return out[:, None], (nk, nv)


def _paged_prefill_attend(cfg, q, k_new, v_new, pool_k, pool_v, scales,
                          write_ids, table, q_start, kv_len, chunk):
    """Paged prefill for one layer: write the chunk's KV rows directly
    into pool blocks, then attend causally over the table's blocks.

    q/k_new/v_new: (1, C, H|K, D) with C a multiple of the pool block
    size; write_ids: (C // bs,) physical block per chunk block (trash 0
    for rows that must not land anywhere — bucket padding, and the
    recompute-baseline's shared prefix); table: (1, max_blocks) read
    table; q_start: (1,) absolute position of the chunk's first row;
    kv_len: (1,) valid rows incl. this chunk.  Seeded blocks (shared
    prefix, resumed history) are attended without being recomputed —
    causality against absolute positions does the masking.

    ``write_ids=None`` switches to the *verify* write layout (speculative
    decoding): q/k_new/v_new are (B, C) candidate rows starting at an
    arbitrary in-block offset ``q_start`` per sequence, so instead of
    whole-block writes each row is scattered individually through
    ``table`` — row ``q_start + j`` lands at block ``table[b, pos // bs]``
    offset ``pos % bs``.  Padding sequences carry all-trash tables, so
    their rows (and any duplicate trash hits) are harmless garbage.
    """
    from repro.kernels.prefill_attention.ops import paged_prefill_attention
    N, bs, K, D = pool_k.shape
    C = q.shape[1]
    if write_ids is None:
        B = q.shape[0]
        mb = table.shape[1]
        pos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        bi = jnp.clip(pos // bs, 0, mb - 1)
        bt = jnp.take_along_axis(table, bi, axis=1)     # (B, C) physical
        off = pos % bs
        if scales is not None:
            k_scale, v_scale = scales
            kq, ksc = quantize_kv(k_new)
            vq, vsc = quantize_kv(v_new)
            nk = pool_k.at[bt, off].set(kq)
            nv = pool_v.at[bt, off].set(vq)
            nks = k_scale.at[bt, off].set(ksc)
            nvs = v_scale.at[bt, off].set(vsc)
            out = paged_prefill_attention(
                q, nk, nv, table, q_start, kv_len, k_scale=nks, v_scale=nvs,
                softcap=cfg.attn_logit_softcap, chunk=chunk)
            return out, (nk, nv, nks, nvs)
        nk = pool_k.at[bt, off].set(k_new.astype(pool_k.dtype))
        nv = pool_v.at[bt, off].set(v_new.astype(pool_v.dtype))
        out = paged_prefill_attention(q, nk, nv, table, q_start, kv_len,
                                      softcap=cfg.attn_logit_softcap,
                                      chunk=chunk)
        return out, (nk, nv)
    kb = k_new[0].reshape(C // bs, bs, K, D)
    vb = v_new[0].reshape(C // bs, bs, K, D)
    if scales is not None:
        k_scale, v_scale = scales
        kq, ksc = quantize_kv(kb)
        vq, vsc = quantize_kv(vb)
        nk = pool_k.at[write_ids].set(kq)
        nv = pool_v.at[write_ids].set(vq)
        nks = k_scale.at[write_ids].set(ksc)
        nvs = v_scale.at[write_ids].set(vsc)
        out = paged_prefill_attention(
            q, nk, nv, table, q_start, kv_len, k_scale=nks, v_scale=nvs,
            softcap=cfg.attn_logit_softcap, chunk=chunk)
        return out, (nk, nv, nks, nvs)
    nk = pool_k.at[write_ids].set(kb.astype(pool_k.dtype))
    nv = pool_v.at[write_ids].set(vb.astype(pool_v.dtype))
    out = paged_prefill_attention(q, nk, nv, table, q_start, kv_len,
                                  softcap=cfg.attn_logit_softcap,
                                  chunk=chunk)
    return out, (nk, nv)


def block_apply(cfg, p, x, positions, *,
                cache_k=None, cache_v=None, cache_scales=None, kv_len=None,
                block_tables=None, paged_prefill=None, chunk=1024):
    """One transformer block. Returns (x, aux, new_kv) where new_kv is
    (k, v) or (k, v, k_scale, v_scale) for the int8 cache.

    Without cache: full self-attention over x (train / prefill).
    With cache (decode): x is (B, 1, D); the new KV row is written at
    ``kv_len`` and attention runs over the whole cache.  With
    ``block_tables`` the cache is paged: cache_k/v are (N, bs, K, D) pool
    slices and reads gather only live blocks.  ``paged_prefill`` (a dict
    of write_ids/table/q_start/kv_len) switches the paged path to the
    multi-row chunk prefill: KV written straight into pool blocks,
    attention causal over the table's blocks.
    """
    h = apply_norm(cfg, p["ln1"], x)
    # SP boundary: norm runs on the seq-sharded carry; attention needs the
    # full sequence, so the gather happens here (post-norm, bf16).
    h = constrain(h, "batch", "seq", "embed_act")
    pos1d = positions[0] if cfg.m_rope else positions
    if cache_k is None:
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        attn = A.chunked_attention(
            q, k, v, causal=True, q_positions=pos1d, kv_positions=pos1d,
            softcap=cfg.attn_logit_softcap, window=cfg.sliding_window,
            chunk=chunk)
        new_kv = (k, v)
    elif block_tables is not None and paged_prefill is not None:
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        attn, new_kv = _paged_prefill_attend(cfg, q, k, v, cache_k, cache_v,
                                             cache_scales, chunk=chunk,
                                             **paged_prefill)
    elif block_tables is not None:
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        attn, new_kv = _paged_attend(cfg, q, k, v, cache_k, cache_v,
                                     cache_scales, block_tables, kv_len,
                                     chunk)
    else:
        from repro.distributed.collectives import seq_sharded_decode_attention
        q, k, v = A.qkv_project(cfg, p["attn"], h, positions)
        ks, vs = cache_scales if cache_scales is not None else (None, None)
        attn, *new_kv = seq_sharded_decode_attention(
            q, cache_k, cache_v, k, v, kv_len, k_scale=ks, v_scale=vs,
            softcap=cfg.attn_logit_softcap, chunk=chunk)
        new_kv = tuple(new_kv)
    attn = A.attn_output(cfg, p["attn"], attn)
    if cfg.parallel_block:
        ffn, aux = _ffn_apply(cfg, p, h)
        x = x + attn + ffn
    else:
        x = x + attn
        h2 = apply_norm(cfg, p["ln2"], x)
        ffn, aux = _ffn_apply(cfg, p, h2)
        x = x + ffn
    # carry leaves the block sequence-sharded (training SP; no-op otherwise)
    x = constrain(x, "batch", "seq_sp", "embed_act")
    return x, aux, new_kv


_REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _scan_blocks(cfg, stacked, x, positions, *, remat, cache=None,
                 collect_kv=False, paged_prefill=None, chunk=1024):
    """Scan the homogeneous block stack. Returns (x, aux_sum, (ks, vs)).

    ``collect_kv`` stacks each layer's fresh K/V as scan outputs (prefill);
    training leaves it off so no (L, B, S, K, D) buffer is ever requested.
    """

    quant = isinstance(cache, (QuantKVCache, QuantPagedKVCache))
    tables = getattr(cache, "block_tables", None)

    def body_nocache(carry, p):
        h, aux = carry
        h, a, kv = block_apply(cfg, p, h, positions, chunk=chunk)
        ys = kv if collect_kv else None
        return (h, aux + a), ys

    def body_cache(carry, layer):
        h, aux = carry
        if quant:
            p, ck, cv, ks, vs = layer
            scales = (ks, vs)
        else:
            p, ck, cv = layer
            scales = None
        h, a, kv = block_apply(cfg, p, h, positions,
                               cache_k=ck, cache_v=cv, cache_scales=scales,
                               kv_len=cache.length, block_tables=tables,
                               paged_prefill=paged_prefill, chunk=chunk)
        return (h, aux + a), kv

    body = body_cache if cache is not None else body_nocache
    if remat and cfg.remat != "none":
        policy = _REMAT_POLICIES.get(cfg.remat, _REMAT_POLICIES["full"])
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    carry0 = (x, jnp.zeros((), jnp.float32))
    if cache is None:
        (x, aux), ys = jax.lax.scan(body, carry0, stacked)
        kv = ys if collect_kv else None
    else:
        xs = ((stacked, cache.k, cache.v, cache.k_scale, cache.v_scale)
              if quant else (stacked, cache.k, cache.v))
        (x, aux), kv = jax.lax.scan(body, carry0, xs)
    return x, aux, kv


def _apply_backbone(cfg, params, tokens, positions, *, remat,
                    cache: KVCache | None = None, collect_kv=False,
                    paged_prefill=None, chunk=1024):
    compute_dt = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], tokens, compute_dt)
    aux_total = jnp.zeros((), jnp.float32)
    quant = isinstance(cache, (QuantKVCache, QuantPagedKVCache))
    paged = isinstance(cache, (PagedKVCache, QuantPagedKVCache))
    dense_caches = []
    n_dense = len(params.get("dense_blocks", ()))
    for i, bp in enumerate(params.get("dense_blocks", ())):
        ck = cv = scales = tables = None
        kl = None
        if cache is not None:
            ck, cv, kl = cache.k[i], cache.v[i], cache.length
            if quant:
                scales = (cache.k_scale[i], cache.v_scale[i])
            if paged:
                tables = cache.block_tables
        x, a, kv = block_apply(cfg, bp, x, positions,
                               cache_k=ck, cache_v=cv, cache_scales=scales,
                               kv_len=kl, block_tables=tables,
                               paged_prefill=paged_prefill, chunk=chunk)
        aux_total += a
        if cache is not None or collect_kv:
            dense_caches.append(kv)
    sub = None
    if cache is not None:
        # slice off the unrolled dense layers; only the stacked pools /
        # caches have a leading layer axis (block_tables and length don't)
        sub = jax.tree_util.tree_map(
            lambda c: c[n_dense:] if c.ndim > 2 else c, cache)
        sub = sub._replace(length=cache.length)
    x, aux, kv = _scan_blocks(cfg, params["blocks"], x, positions,
                              remat=remat, cache=sub,
                              collect_kv=collect_kv,
                              paged_prefill=paged_prefill, chunk=chunk)
    aux_total += aux
    x = apply_norm(cfg, params["ln_f"], x)
    new_cache = None
    if kv is not None:
        if dense_caches:
            kv = tuple(
                jnp.concatenate([jnp.stack([c[j] for c in dense_caches]),
                                 kv[j]])
                for j in range(len(kv)))
        length = (cache.length if cache is not None
                  else jnp.full((tokens.shape[0],), tokens.shape[1],
                                jnp.int32))
        if paged:
            if len(kv) == 4:
                new_cache = QuantPagedKVCache(
                    k=kv[0], v=kv[1], k_scale=kv[2], v_scale=kv[3],
                    block_tables=cache.block_tables, length=length)
            else:
                new_cache = PagedKVCache(k=kv[0], v=kv[1],
                                         block_tables=cache.block_tables,
                                         length=length)
        elif len(kv) == 4:
            new_cache = QuantKVCache(k=kv[0], v=kv[1], k_scale=kv[2],
                                     v_scale=kv[3], length=length)
        else:
            new_cache = KVCache(k=kv[0], v=kv[1], length=length)
    return x, aux_total, new_cache


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def default_positions(cfg, tokens: jax.Array) -> jax.Array:
    B, S = tokens.shape[0], tokens.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.m_rope:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(cfg, params, tokens, positions=None, *, remat=True, chunk=1024):
    """Training forward: full logits (B, S, V) fp32 + aux loss."""
    if positions is None:
        positions = default_positions(cfg, tokens)
    x, aux, _ = _apply_backbone(cfg, params, tokens, positions, remat=remat,
                                chunk=chunk)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg, aux


def prefill(cfg, params, tokens, positions=None, *, cache_dtype="bfloat16",
            max_len: int | None = None, chunk=1024, last_pos=None):
    """Prefill: last-position logits (B, V) + KV cache sized to ``max_len``.

    ``last_pos`` (B,) reads logits at an arbitrary position instead of the
    final one — the bucketed-prefill path right-pads prompts to a compile
    bucket, so the real last token sits at ``prompt_len - 1`` (causality
    keeps its logits independent of the padding that follows).
    """
    if positions is None:
        positions = default_positions(cfg, tokens)
    x, _, cache = _apply_backbone(cfg, params, tokens, positions, remat=False,
                                  collect_kv=True, chunk=chunk)
    Sq = tokens.shape[1]
    max_len = max_len or Sq
    cdt = dtype_of(cache_dtype)

    def grow(c):
        if max_len == Sq:
            return c.astype(cdt)
        out = jnp.zeros(c.shape[:2] + (max_len,) + c.shape[3:], cdt)
        return out.at[:, :, :Sq].set(c.astype(cdt))

    cache = KVCache(k=grow(cache.k), v=grow(cache.v), length=cache.length)
    if last_pos is None:
        last = x[:, -1:]
    else:
        last = x[jnp.arange(x.shape[0]), last_pos][:, None]
    lg = lm_logits(params["embed"], last, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], cache


def prefill_paged(cfg, params, tokens, cache, write_ids, table, *,
                  q_start, kv_len, last_idx, chunk=1024):
    """Cache-seeded chunked prefill: write one prompt chunk straight into
    paged pool blocks and attend over everything already seeded.

    tokens: (1, C) chunk (C a multiple of the pool block size; rows past
    the real prompt are padding whose writes land in the trash block via
    ``write_ids``); cache: Paged/QuantPagedKVCache whose pools are shared
    by every slot; write_ids: (C // block_size,) physical block per chunk
    block; table: (1, max_blocks) the request's read table; q_start: (1,)
    absolute position of the chunk's first token; kv_len: (1,) valid KV
    rows including this chunk's real tokens; last_idx: row whose logits
    to return (the chunk's last real token).

    Computation starts at the first unseeded token: rows before
    ``q_start`` (shared prefix blocks, a preemption victim's surviving
    history) are *read* through the table, never re-run — this is what
    the bucketed dense-prefill + scatter path could not do.  Returns
    ((1, V) logits at ``last_idx``, cache with updated pools).
    """
    B, C = tokens.shape
    pos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(pos, (B, C))
    if cfg.m_rope:
        pos = jnp.broadcast_to(pos[None], (3, B, C))
    x, _, new_cache = _apply_backbone(
        cfg, params, tokens, pos, remat=False, cache=cache, chunk=chunk,
        paged_prefill=dict(write_ids=write_ids, table=table,
                           q_start=q_start, kv_len=kv_len))
    last = x[jnp.arange(B), last_idx][:, None]
    lg = lm_logits(params["embed"], last, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], new_cache


def verify_paged(cfg, params, tokens, cache, table, *, q_start, kv_len,
                 chunk=1024):
    """Speculative-decode verify pass: score ``k + 1`` candidate tokens per
    sequence in one batched target-model call.

    tokens: (B, C) per-slot ``[t_0, d_1 .. d_k]`` — the pending greedy
    token plus the drafter's proposals; cache: Paged/QuantPagedKVCache;
    table: (B, max_blocks) per-slot read tables (provisionally grown to
    cover the candidate rows; padding slots all-trash); q_start: (B,)
    committed rows per slot (candidate row ``j`` sits at absolute position
    ``q_start + j``); kv_len: (B,) ``q_start + C`` for live slots.

    Unlike :func:`prefill_paged` this returns logits at *every* candidate
    position — ``(B, C, V)`` with row ``j`` giving the target distribution
    after ``t_0, d_1 .. d_j`` — so greedy acceptance can take the longest
    drafter prefix matching the target's argmax chain.  Candidate KV rows
    are row-scattered through ``table`` (``write_ids=None`` layout), so
    accepted rows are already in place and the rejected tail sits in
    blocks the engine hands back via ``release_provisional``.
    """
    B, C = tokens.shape
    pos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(pos, (B, C))
    if cfg.m_rope:
        pos = jnp.broadcast_to(pos[None], (3, B, C))
    x, _, new_cache = _apply_backbone(
        cfg, params, tokens, pos, remat=False, cache=cache, chunk=chunk,
        paged_prefill=dict(write_ids=None, table=table,
                           q_start=q_start, kv_len=kv_len))
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg, new_cache


def decode_step(cfg, params, tokens, cache, *, chunk=2048):
    """One decode step. tokens: (B, 1) -> logits (B, V), updated cache.

    ``cache`` may be any of the four cache types; the paged variants route
    attention through the block-table gather path (Pallas kernel on TPU,
    jnp oracle otherwise)."""
    B = tokens.shape[0]
    pos = cache.length[:, None]
    if cfg.m_rope:
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    x, _, new_cache = _apply_backbone(cfg, params, tokens, pos, remat=False,
                                      cache=cache, chunk=chunk)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    new_cache = new_cache._replace(length=cache.length + 1)
    return lg[:, 0], new_cache
