"""GoogLeNet (Inception-v1) — the paper's evaluation network (BVLC
GoogLeNet, Szegedy et al. CVPR'15), in JAX/NHWC.

Auxiliary classifier heads are training-time only in the original; the
paper only runs inference, so they are omitted (noted in DESIGN.md).  The
3x3 conv hot-spot has a Pallas im2col kernel in `repro.kernels.conv2d`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import dtype_of
from repro.models.layers.conv import (avg_pool, conv_table, global_avg_pool,
                                      lrn, max_pool, relu_conv)
from repro.models.layers.module import bias, init_table, weight

# (1x1, 3x3reduce, 3x3, 5x5reduce, 5x5, pool-proj) per inception module
INCEPTION_SPECS = {
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}
_STAGE_INPUT = {
    "3a": 192, "3b": 256, "4a": 480, "4b": 512, "4c": 512, "4d": 512,
    "4e": 528, "5a": 832, "5b": 832,
}


def inception_table(cin: int, spec):
    c1, c3r, c3, c5r, c5, pp = spec
    return {
        "b1": conv_table(1, 1, cin, c1),
        "b2r": conv_table(1, 1, cin, c3r),
        "b2": conv_table(3, 3, c3r, c3),
        "b3r": conv_table(1, 1, cin, c5r),
        "b3": conv_table(5, 5, c5r, c5),
        "b4": conv_table(1, 1, cin, pp),
    }


def inception(params, x: jax.Array) -> jax.Array:
    b1 = relu_conv(params["b1"], x)
    b2 = relu_conv(params["b2"], relu_conv(params["b2r"], x))
    b3 = relu_conv(params["b3"], relu_conv(params["b3r"], x))
    b4 = relu_conv(params["b4"], max_pool(x, 3, 1, "SAME"))
    return jnp.concatenate([b1, b2, b3, b4], axis=-1)


def model_table(cfg):
    num_classes = cfg.vocab_size   # 1000 for ILSVRC
    t = {
        "stem1": conv_table(7, 7, 3, 64),
        "stem2r": conv_table(1, 1, 64, 64),
        "stem2": conv_table(3, 3, 64, 192),
        "fc_w": weight((1024, num_classes), (None, "vocab"), stddev=0.01),
        "fc_b": bias((num_classes,), ("vocab",)),
    }
    for name, spec in INCEPTION_SPECS.items():
        t[f"inc{name}"] = inception_table(_STAGE_INPUT[name], spec)
    return t


def init(cfg, key: jax.Array):
    return init_table(key, model_table(cfg), cfg.param_dtype)


def forward(cfg, params, images: jax.Array) -> jax.Array:
    """images: (B, 224, 224, 3) -> logits (B, num_classes) fp32."""
    x = images.astype(dtype_of(cfg.compute_dtype))
    x = relu_conv(params["stem1"], x, stride=2)          # 112x112x64
    x = max_pool(x, 3, 2)                                # 56x56
    x = lrn(x)
    x = relu_conv(params["stem2r"], x)
    x = relu_conv(params["stem2"], x)                    # 56x56x192
    x = lrn(x)
    x = max_pool(x, 3, 2)                                # 28x28
    x = inception(params["inc3a"], x)
    x = inception(params["inc3b"], x)
    x = max_pool(x, 3, 2)                                # 14x14
    for name in ("4a", "4b", "4c", "4d", "4e"):
        x = inception(params[f"inc{name}"], x)
    x = max_pool(x, 3, 2)                                # 7x7
    x = inception(params["inc5a"], x)
    x = inception(params["inc5b"], x)                    # 7x7x1024
    x = global_avg_pool(x)                               # (B, 1024)
    logits = (x.astype(jnp.float32) @ params["fc_w"].astype(jnp.float32)
              + params["fc_b"].astype(jnp.float32))
    return logits


def predict(cfg, params, images: jax.Array):
    """Paper-style inference output: (top1 label, confidence) per image."""
    lg = forward(cfg, params, images)
    probs = jax.nn.softmax(lg, axis=-1)
    conf = jnp.max(probs, axis=-1)
    label = jnp.argmax(probs, axis=-1)
    return label, conf, probs
