"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv stem) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, frames, D).
Positions use sinusoidal additive embeddings (shape-agnostic; Whisper's
learned decoder table is a finite-size deviation noted in DESIGN.md).

Decode state: per-layer self-attention KV cache (growable) + per-layer
cross-attention KV computed once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import dtype_of
from repro.distributed.sharding import constrain
from repro.models.layers import attention as A
from repro.models.layers.embedding import embed, embedding_table, logits as lm_logits
from repro.models.layers.mlp import gelu_mlp, gelu_mlp_table
from repro.models.layers.module import init_table, stack_table
from repro.models.layers.norms import apply_norm, norm_table


class EncDecState(NamedTuple):
    self_k: jax.Array    # (L, B, S, K, D)
    self_v: jax.Array
    cross_k: jax.Array   # (L, B, F, K, D)
    cross_v: jax.Array
    length: jax.Array    # (B,)


def sinusoid(seq: int, d: int, offset: jax.Array | int = 0) -> jax.Array:
    """Sinusoidal position embedding (S, D) fp32, positions offset+[0,S)."""
    pos = jnp.arange(seq, dtype=jnp.float32) + jnp.asarray(offset, jnp.float32)
    half = d // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_block_table(cfg):
    return {"ln1": norm_table(cfg), "attn": A.attention_table(cfg),
            "ln2": norm_table(cfg), "mlp": gelu_mlp_table(cfg.d_model, cfg.d_ff)}


def dec_block_table(cfg):
    return {"ln1": norm_table(cfg), "self_attn": A.attention_table(cfg),
            "ln2": norm_table(cfg), "cross_attn": A.cross_attention_table(cfg),
            "ln3": norm_table(cfg), "mlp": gelu_mlp_table(cfg.d_model, cfg.d_ff)}


def lm_table(cfg):
    return {
        "embed": embedding_table(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "enc_blocks": stack_table(enc_block_table(cfg),
                                  cfg.encdec.num_encoder_layers),
        "enc_ln_f": norm_table(cfg),
        "dec_blocks": stack_table(dec_block_table(cfg), cfg.num_layers),
        "dec_ln_f": norm_table(cfg),
    }


def init(cfg, key: jax.Array):
    return init_table(key, lm_table(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg, params, frames: jax.Array, *, remat=False,
           chunk=1024) -> jax.Array:
    """frames: (B, F, D) precomputed embeddings -> (B, F, D)."""
    B, F, D = frames.shape
    x = frames.astype(dtype_of(cfg.compute_dtype))
    x = x + sinusoid(F, D).astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))

    def body(h, p):
        a = apply_norm(cfg, p["ln1"], h)
        q, k, v = A.qkv_project(cfg, p["attn"], a, None)  # no RoPE
        attn = A.chunked_attention(q, k, v, causal=False,
                                   q_positions=pos, kv_positions=pos,
                                   chunk=chunk)
        h = h + A.attn_output(cfg, p["attn"], attn)
        h = h + gelu_mlp(p["mlp"], apply_norm(cfg, p["ln2"], h))
        return constrain(h, "batch", "seq", "embed_act"), None

    if remat and cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_ln_f"], x)


def cross_kv(cfg, params, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V from the encoder output."""

    def body(_, p):
        _, k, v = A.qkv_project(cfg, p["cross_attn"], enc_out, None)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
    return ks, vs


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _dec_block(cfg, p, x, positions, enc_out=None, *, cache=None,
               cross=None, chunk=1024):
    """One decoder block. cache: (ck, cv, kv_len) or None.
    cross: (k, v) precomputed or None (computed from enc_out)."""
    B = x.shape[0]
    h = apply_norm(cfg, p["ln1"], x)
    if cache is None:
        q, k, v = A.qkv_project(cfg, p["self_attn"], h, None)
        attn = A.chunked_attention(q, k, v, causal=True,
                                   q_positions=positions,
                                   kv_positions=positions, chunk=chunk)
        nk, nv = k, v
    else:
        from repro.distributed.collectives import seq_sharded_decode_attention
        ck, cv, kv_len = cache
        q, k, v = A.qkv_project(cfg, p["self_attn"], h, None)
        attn, nk, nv = seq_sharded_decode_attention(
            q, ck, cv, k, v, kv_len, chunk=chunk)
    x = x + A.attn_output(cfg, p["self_attn"], attn)

    h2 = apply_norm(cfg, p["ln2"], x)
    if cross is not None:
        ck_, cv_ = cross
    else:
        _, ck_, cv_ = A.qkv_project(cfg, p["cross_attn"], enc_out, None)
    q2 = jnp.einsum("bsd,dhk->bshk", h2,
                    p["cross_attn"]["wq"].astype(h2.dtype))
    if cfg.qkv_bias:
        q2 = q2 + p["cross_attn"]["bq"].astype(h2.dtype)
    F = ck_.shape[1]
    fpos = jnp.arange(F, dtype=jnp.int32)
    cattn = A.chunked_attention(q2, ck_.astype(h2.dtype), cv_.astype(h2.dtype),
                                causal=False, q_positions=positions,
                                kv_positions=fpos, chunk=chunk)
    x = x + A.attn_output(cfg, p["cross_attn"], cattn)
    x = x + gelu_mlp(p["mlp"], apply_norm(cfg, p["ln3"], x))
    return constrain(x, "batch", "seq_sp", "embed_act"), nk, nv


def _decoder(cfg, params, tokens, enc_out=None, *, state=None, remat=True,
             collect=False, pos_offset=0, chunk=1024):
    compute_dt = dtype_of(cfg.compute_dtype)
    B, Sq = tokens.shape
    x = embed(params["embed"], tokens, compute_dt)
    off = state.length if state is not None else pos_offset
    if isinstance(off, jax.Array) and off.ndim == 1:
        # per-sequence offsets: add per-row sinusoid
        pe = jax.vmap(lambda o: sinusoid(Sq, cfg.d_model, o))(off)
        positions = off[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    else:
        pe = sinusoid(Sq, cfg.d_model, off)[None]
        positions = jnp.broadcast_to(
            jnp.arange(Sq, dtype=jnp.int32) + off, (B, Sq))
    x = x + pe.astype(x.dtype)

    if state is None:
        def body(carry, p):
            h = carry
            h, nk, nv = _dec_block(cfg, p, h, positions, enc_out, chunk=chunk)
            return h, (nk, nv) if collect else None
        if remat and cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        x, ys = jax.lax.scan(body, x, params["dec_blocks"])
        ks, vs = ys if collect else (None, None)
        new_state = (ks, vs)
    else:
        def body(carry, layer):
            h = carry
            p, ck, cv, xk, xv = layer
            h, nk, nv = _dec_block(cfg, p, h, positions, None,
                                   cache=(ck, cv, state.length),
                                   cross=(xk, xv), chunk=chunk)
            return h, (nk, nv)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], state.self_k, state.self_v,
                      state.cross_k, state.cross_v))
        new_state = (ks, vs)
    x = apply_norm(cfg, params["dec_ln_f"], x)
    return x, new_state


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, frames, *, remat=True, chunk=1024):
    """Training: encoder on frames + full decoder logits."""
    enc_out = encode(cfg, params, frames, remat=remat, chunk=chunk)
    x, _ = _decoder(cfg, params, tokens, enc_out, remat=remat, chunk=chunk)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg, jnp.zeros((), jnp.float32)


def prefill(cfg, params, tokens, frames, *, cache_dtype="bfloat16",
            max_len=None, chunk=1024):
    B, Sq = tokens.shape
    cdt = dtype_of(cache_dtype)
    enc_out = encode(cfg, params, frames, chunk=chunk)
    xk, xv = cross_kv(cfg, params, enc_out)
    x, (ks, vs) = _decoder(cfg, params, tokens, enc_out, collect=True,
                           remat=False, chunk=chunk)
    max_len = max_len or Sq
    def grow(c):
        if max_len == Sq:
            return c.astype(cdt)
        out = jnp.zeros(c.shape[:2] + (max_len,) + c.shape[3:], cdt)
        return out.at[:, :, :Sq].set(c.astype(cdt))
    st = EncDecState(self_k=grow(ks), self_v=grow(vs),
                     cross_k=xk.astype(cdt), cross_v=xv.astype(cdt),
                     length=jnp.full((B,), Sq, jnp.int32))
    lg = lm_logits(params["embed"], x[:, -1:], cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    return lg[:, 0], st


def decode_step(cfg, params, tokens, state: EncDecState, *, chunk=2048):
    x, (ks, vs) = _decoder(cfg, params, tokens, None, state=state, chunk=chunk)
    lg = lm_logits(params["embed"], x, cfg.tie_embeddings,
                   cfg.final_logit_softcap)
    new_state = state._replace(self_k=ks, self_v=vs, length=state.length + 1)
    return lg[:, 0], new_state


def init_decode_state(cfg, batch: int, max_len: int,
                      cache_dtype="bfloat16") -> EncDecState:
    cdt = dtype_of(cache_dtype)
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    F = cfg.encdec.num_encoder_frames
    return EncDecState(
        self_k=jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), cdt),
        self_v=jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), cdt),
        cross_k=jnp.zeros((L, batch, F, cfg.num_kv_heads, hd), cdt),
        cross_v=jnp.zeros((L, batch, F, cfg.num_kv_heads, hd), cdt),
        length=jnp.zeros((batch,), jnp.int32))
