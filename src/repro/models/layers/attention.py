"""Attention: GQA/MHA with RoPE / M-RoPE, qk-norm, bias options, and a
memory-efficient chunked online-softmax core.

The chunked core (`chunked_attention`) is the pure-jnp oracle shared by the
Pallas flash kernels (`repro.kernels.flash_attention` / `decode_attention`);
it scans KV blocks carrying (max, sum, acc) so the S x S score matrix is never
materialized — this is what makes 32k prefill lowering memory-sane.

Decode against a sequence-sharded KV cache uses the LSE-merge path in
`repro.distributed.collectives` built on the `return_residuals=True` output.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.module import bias, scale, weight
from repro.models.layers.norms import head_rmsnorm
from repro.models.layers.rope import apply_m_rope, apply_rope

NEG_INF = -1e30


def attention_table(cfg, d_model: int | None = None):
    """Parameter table for one attention block."""
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    t = {
        "wq": weight((d, cfg.num_heads, hd), ("embed", "heads", None)),
        "wk": weight((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", None)),
        "wv": weight((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", None)),
        "wo": weight((cfg.num_heads, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = bias((cfg.num_heads, hd), ("heads", None))
        t["bk"] = bias((cfg.num_kv_heads, hd), ("kv_heads", None))
        t["bv"] = bias((cfg.num_kv_heads, hd), ("kv_heads", None))
    if cfg.qk_norm:
        t["q_norm"] = scale((hd,), (None,))
        t["k_norm"] = scale((hd,), (None,))
    return t


def cross_attention_table(cfg, d_model: int | None = None):
    """Cross-attention (enc-dec): same shape family, separate KV source."""
    return attention_table(cfg, d_model)


def qkv_project(cfg, params, x: jax.Array,
                positions: jax.Array | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> q (B, S, H, hd), k/v (B, S, K, hd), RoPE applied."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if positions is not None:
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.rope_theta, cfg.m_rope_sections)
            k = apply_m_rope(k, positions, cfg.rope_theta, cfg.m_rope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    return q, k, v


class AttnResiduals(NamedTuple):
    """Per-query-row log-sum-exp residuals for distributed (LSE) merging."""
    out: jax.Array   # (B, Sq, H, D) un-normalized accumulator / or normalized
    m: jax.Array     # (B, H, Sq) running max
    l: jax.Array     # (B, H, Sq) running sum


def _mask_bias(q_pos, kv_pos, *, causal: bool, window: int,
               kv_len=None) -> jax.Array:
    """Additive mask bias (..., Sq, C) in fp32; 0 where attended."""
    # q_pos: (B, Sq); kv_pos: (C,) or (B, C)
    if kv_pos.ndim == 1:
        kv = kv_pos[None, None, :]
    else:
        kv = kv_pos[:, None, :]
    qp = q_pos[:, :, None]
    allowed = jnp.ones(jnp.broadcast_shapes(qp.shape, kv.shape), bool)
    if causal:
        allowed &= kv <= qp
    if window:
        allowed &= kv > qp - window
    if kv_len is not None:
        allowed &= kv < kv_len[:, None, None]
    return jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      q_positions: jax.Array | None = None,
                      kv_positions: jax.Array | None = None,
                      kv_len: jax.Array | None = None,
                      softcap: float = 0.0,
                      window: int = 0,
                      chunk: int = 1024,
                      return_residuals: bool = False):
    """Online-softmax attention, scanning KV in chunks.

    Args:
      q: (B, Sq, H, D); k/v: (B, Skv, K, D) with H % K == 0 (GQA).
      q_positions: (B, Sq) absolute positions (defaults to arange).
      kv_positions: (B, Skv) or (Skv,) absolute positions of cache slots.
      kv_len: (B,) valid cache length per sequence (decode masking).
      return_residuals: also return (m, l) LSE stats for distributed merge.

    Returns:
      out (B, Sq, H, D) [, AttnResiduals].
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale_ = 1.0 / math.sqrt(D)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    if kv_positions is None:
        kv_positions = jnp.arange(Skv, dtype=jnp.int32)

    chunk = min(chunk, Skv)
    n_chunks = math.ceil(Skv / chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # Padded slots get a huge positive position: masked by causality and
        # by any kv_len bound; for the non-causal/no-len case we add a bound.
        if kv_positions.ndim == 1:
            kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=10**9)
        else:
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                                   constant_values=10**9)
        if kv_len is None and not causal:
            kv_len = jnp.full((B,), Skv, jnp.int32)

    qg = q.reshape(B, Sq, K, G, D)

    def seg(arr, i):
        return jax.lax.dynamic_slice_in_dim(arr, i * chunk, chunk,
                                            axis=1 if arr.ndim > 1 else 0)

    def body(carry, i):
        m, l, acc = carry
        k_c = seg(k, i)                                   # (B, C, K, D)
        v_c = seg(v, i)
        kp_c = seg(kv_positions, i)                       # (C,) or (B, C)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_c).astype(jnp.float32)
        s = s * scale_
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mb = _mask_bias(q_positions, kp_c, causal=causal, window=window,
                        kv_len=kv_len)                    # (B, Sq, C)
        s = s + mb[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))       # (B, K, G, Sq)
        # Guard fully-masked rows: keep m finite so exp() stays 0, not nan.
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[..., None])                # (B, K, G, Sq, C)
        corr = jnp.exp(jnp.clip(m - m_new, None, 0.0))
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_c.dtype), v_c)
        acc = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(n_chunks, dtype=jnp.int32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(B, K * G, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)
    if return_residuals:
        res = AttnResiduals(out=out,
                            m=m.reshape(B, H, Sq), l=l.reshape(B, H, Sq))
        return out, res
    return out


def merge_lse(parts: list[AttnResiduals]) -> jax.Array:
    """Merge attention partials computed over disjoint KV shards.

    Each part's `out` is already normalized by its local `l`; we re-weight by
    softmax-consistent factors: w_i = l_i * exp(m_i - m*) / sum_j l_j exp(...).
    """
    m_star = parts[0].m
    for p in parts[1:]:
        m_star = jnp.maximum(m_star, p.m)
    num = 0.0
    den = 0.0
    for p in parts:
        w = p.l * jnp.exp(jnp.clip(p.m - m_star, None, 0.0))   # (B, H, Sq)
        num = num + p.out.astype(jnp.float32) * w.transpose(0, 2, 1)[..., None]
        den = den + w.transpose(0, 2, 1)[..., None]
    return (num / jnp.maximum(den, 1e-30)).astype(parts[0].out.dtype)


def attn_output(cfg, params, attn: jax.Array) -> jax.Array:
    """attn: (B, S, H, hd) -> (B, S, D)."""
    out = jnp.einsum("bshk,hkd->bsd", attn, params["wo"].astype(attn.dtype))
    return constrain(out, "batch", "seq", "embed_act")


def self_attention(cfg, params, x: jax.Array, positions: jax.Array,
                   *, causal: bool = True, chunk: int = 1024) -> jax.Array:
    """Full-sequence self-attention (train / prefill), no cache."""
    q, k, v = qkv_project(cfg, params, x, positions)
    pos1d = positions[0] if cfg.m_rope else positions  # mask uses temporal ids
    out = chunked_attention(q, k, v, causal=causal,
                            q_positions=pos1d, kv_positions=pos1d,
                            softcap=cfg.attn_logit_softcap,
                            window=cfg.sliding_window, chunk=chunk)
    return attn_output(cfg, params, out)
