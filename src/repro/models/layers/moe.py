"""Mixture-of-Experts: router + three dispatch strategies.

Routing (top-k + aux loss) is computed with plain jnp ops outside any
shard_map, so all strategies share identical expert assignments:

  * ``ep_a2a``  — production EP: tokens are sequence-sharded over the
    ``model`` mesh axis, dispatched to expert owners with a fixed-capacity
    ``lax.all_to_all`` (DeepSpeed-MoE style), expert FFN runs on the owner,
    results return via a second all-to-all.  Used when a mesh is active and
    the token count divides the model axis (train / prefill).
  * ``einsum``  — GShard one-hot dispatch; cheap only when per-group capacity
    is tiny, so it serves decode (S==1) and small test shapes.
  * ``dense``   — every expert applied to every token, masked combine; the
    O(E x T) oracle for unit tests.

DeepSeekMoE extensions: shared experts (always-on, fused into one SwiGLU) and
``first_k_dense`` leading dense layers are handled in the block, not here.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, current_mesh, current_rules
from repro.models.layers.module import weight


def moe_table(d_model: int, num_experts: int, d_ff_expert: int):
    """Router + stacked expert SwiGLU weights (expert dim sharded for EP)."""
    e, d, f = num_experts, d_model, d_ff_expert
    return {
        "router": weight((d, e), ("embed", None), stddev=0.02),
        "w_gate": weight((e, d, f), ("experts", "embed", "ff_expert")),
        "w_up": weight((e, d, f), ("experts", "embed", "ff_expert")),
        "w_down": weight((e, f, d), ("experts", "ff_expert", "embed")),
    }


def route(cfg_moe, params, x: jax.Array):
    """Top-k routing decisions + Switch-style load-balance aux loss.

    Args:
      x: (B, S, D) activations.
    Returns:
      idx (B, S, k) int32 expert ids, prob (B, S, k) f32 combine weights,
      aux_loss scalar f32.
    """
    e = cfg_moe.num_experts
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    prob, idx = jax.lax.top_k(probs, cfg_moe.top_k)
    if cfg_moe.norm_topk_prob:
        prob = prob / jnp.maximum(jnp.sum(prob, axis=-1, keepdims=True), 1e-9)
    # aux = E * mean_e( frac_tokens(e) * mean_prob(e) )  (Switch eq. 4)
    one_hot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (B,S,k,E)
    frac = jnp.mean(jnp.sum(one_hot, axis=2), axis=(0, 1))    # (E,)
    mean_p = jnp.mean(probs, axis=(0, 1))                     # (E,)
    aux = e * jnp.sum(frac * mean_p) / cfg_moe.top_k
    return idx, prob.astype(jnp.float32), aux * cfg_moe.router_aux_loss_weight


def expert_ffn(w_gate, w_up, w_down, xs: jax.Array) -> jax.Array:
    """xs: (E, C, D) -> (E, C, D); per-expert SwiGLU."""
    dt = xs.dtype
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------

def moe_dense(cfg_moe, params, x, idx, prob):
    """O(E x T) oracle: every expert on every token, masked combine."""
    B, S, D = x.shape
    e = cfg_moe.num_experts
    xs = jnp.broadcast_to(x.reshape(1, B * S, D), (e, B * S, D))
    ys = expert_ffn(params["w_gate"], params["w_up"], params["w_down"], xs)
    ys = ys.reshape(e, B, S, D)
    combine = jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32)
                      * prob[..., None], axis=2)               # (B,S,E)
    return jnp.einsum("ebsd,bse->bsd", ys.astype(jnp.float32),
                      combine).astype(x.dtype)


# ---------------------------------------------------------------------------
# GShard einsum dispatch (decode / small shapes)
# ---------------------------------------------------------------------------

def moe_einsum(cfg_moe, params, x, idx, prob, *, capacity: int | None = None):
    """One-hot dispatch within per-batch-row groups; capacity per (row, expert)."""
    B, S, D = x.shape
    e, k = cfg_moe.num_experts, cfg_moe.top_k
    if capacity is None:
        capacity = max(1, math.ceil(S * k * cfg_moe.capacity_factor / e))
    # position of each (token, choice) within its expert, per batch row
    sel = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # (B,S,k,E)
    flat = sel.reshape(B, S * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (B,S*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(B, S, k)         # (B,S,k)
    keep = pos < capacity
    disp = (jax.nn.one_hot(idx, e, dtype=x.dtype)[..., :, None]
            * jax.nn.one_hot(pos, capacity, dtype=x.dtype)[..., None, :])
    disp = disp * keep[..., None, None].astype(x.dtype)         # (B,S,k,E,C)
    disp_tok = jnp.sum(disp, axis=2)                            # (B,S,E,C)
    xs = jnp.einsum("bsec,bsd->ebcd", disp_tok, x)              # (E,B,C,D)
    xs = constrain(xs, "experts", "batch", None, None)
    ys = expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                    xs.reshape(e, B * capacity, D))
    ys = constrain(ys.reshape(e, B, capacity, D),
                   "experts", "batch", None, None)
    comb = jnp.sum(disp * prob[..., None, None].astype(x.dtype), axis=2)
    out = jnp.einsum("bsec,ebcd->bsd", comb, ys)
    return constrain(out, "batch", "seq", "embed_act")


# ---------------------------------------------------------------------------
# production EP: all-to-all dispatch under shard_map
# ---------------------------------------------------------------------------

def _positions_within(dest: jax.Array, num_dest: int) -> tuple[jax.Array, jax.Array]:
    """For each entry, its arrival rank among same-destination entries.

    dest: (N,) int32 in [0, num_dest). Returns (pos (N,), counts (num_dest,)).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    counts = jnp.bincount(dest, length=num_dest)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_dest]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    return pos, counts


def _ep_local(x_loc, idx_loc, prob_loc, w_gate, w_up, w_down, *,
              cfg_moe, model_axis: str, model_size: int):
    """Per-device body: dispatch -> all_to_all -> expert FFN -> return."""
    Bl, Sl, D = x_loc.shape
    k = cfg_moe.top_k
    e_local = cfg_moe.num_experts // model_size
    T = Bl * Sl
    xf = x_loc.reshape(T, D)
    ef = idx_loc.reshape(T * k)
    pf = prob_loc.reshape(T * k)
    tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    dest_shard = ef // e_local                                  # (T*k,)
    c_send = max(1, math.ceil(T * k * cfg_moe.capacity_factor / model_size))
    pos, _ = _positions_within(dest_shard, model_size)
    keep = pos < c_send
    slot = dest_shard * c_send + pos                            # (T*k,)
    slot = jnp.where(keep, slot, model_size * c_send)           # drop slot

    send = jnp.zeros((model_size * c_send + 1, D), x_loc.dtype)
    send = send.at[slot].set(xf[tok_of], mode="drop")[:-1]
    send_eid = jnp.full((model_size * c_send + 1,), 0, jnp.int32)
    send_eid = send_eid.at[slot].set(ef % e_local, mode="drop")[:-1]

    recv = jax.lax.all_to_all(
        send.reshape(model_size, c_send, D), model_axis, 0, 0, tiled=False)
    recv_eid = jax.lax.all_to_all(
        send_eid.reshape(model_size, c_send), model_axis, 0, 0, tiled=False)
    R = model_size * c_send
    recv = recv.reshape(R, D)
    recv_eid = recv_eid.reshape(R)

    # second-level fixed capacity per local expert
    c_exp = max(1, math.ceil(R * cfg_moe.capacity_factor / max(e_local, 1)))
    pos2, _ = _positions_within(recv_eid, e_local)
    keep2 = pos2 < c_exp
    slot2 = jnp.where(keep2, recv_eid * c_exp + pos2, e_local * c_exp)
    buf = jnp.zeros((e_local * c_exp + 1, D), x_loc.dtype)
    buf = buf.at[slot2].set(recv, mode="drop")[:-1]

    ys = expert_ffn(w_gate, w_up, w_down, buf.reshape(e_local, c_exp, D))
    ys = ys.reshape(e_local * c_exp, D)

    # route results back through the same slots
    back = jnp.take(jnp.pad(ys, ((0, 1), (0, 0))),
                    jnp.where(keep2, slot2, e_local * c_exp), axis=0)
    ret = jax.lax.all_to_all(
        back.reshape(model_size, c_send, D), model_axis, 0, 0, tiled=False)
    ret = ret.reshape(model_size * c_send, D)
    contrib = jnp.take(jnp.pad(ret, ((0, 1), (0, 0))),
                       jnp.where(keep, slot, model_size * c_send), axis=0)
    contrib = contrib.astype(jnp.float32) * pf[:, None]
    y = jnp.zeros((T, D), jnp.float32).at[tok_of].add(contrib)
    return y.reshape(Bl, Sl, D).astype(x_loc.dtype)


def moe_ep(cfg_moe, params, x, idx, prob, *, mesh, batch_axes, model_axis):
    """Sequence-sharded EP dispatch. x: (B, S, D) with S % model_size == 0."""
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis]
    x = constrain(x, "batch", "seq_model", None)  # reshard: seq over model
    body = partial(_ep_local, cfg_moe=cfg_moe, model_axis=model_axis,
                   model_size=model_size)
    bspec = P(batch_axes, model_axis, None)
    ispec = P(batch_axes, model_axis, None)
    especs = (P(model_axis, None, None),) * 3
    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(bspec, ispec, ispec, *especs),
        out_specs=bspec,
        check_vma=False,
    )(x, idx, prob, params["w_gate"], params["w_up"], params["w_down"])
    return constrain(out, "batch", "seq", "embed_act")


# ---------------------------------------------------------------------------
# strategy selection
# ---------------------------------------------------------------------------

def moe_apply(cfg_moe, params, x: jax.Array, idx, prob) -> jax.Array:
    """Pick dispatch strategy from the active mesh/rules. Differentiable."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is not None and rules is not None:
        model_axis = rules.rules.get("experts")
        if model_axis is not None and isinstance(model_axis, str):
            msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get(model_axis, 1)
            S = x.shape[1]
            if msize > 1 and S % msize == 0 and S >= msize and \
                    cfg_moe.num_experts % msize == 0:
                batch_axes = rules.rules.get("batch")
                return moe_ep(cfg_moe, params, x, idx, prob, mesh=mesh,
                              batch_axes=batch_axes, model_axis=model_axis)
        return moe_einsum(cfg_moe, params, x, idx, prob)
    return moe_einsum(cfg_moe, params, x, idx, prob)
