"""xLSTM layers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with recurrent weights, inherently sequential).

mLSTM is a decayed outer-product recurrence, so it reuses
`ssm.chunked_linear_attn`; the max(|n.q|, 1) normalizer is obtained by
appending a ones-column to V and scanning once (num and den share the state).
sLSTM has hidden-to-gate recurrence (R h_{t-1}) and therefore runs as a
`lax.scan` over time with the standard exp-gate stabilizer m_t.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.module import bias, scale, weight
from repro.models.layers.norms import rmsnorm
from repro.models.layers.ssm import (chunked_linear_attn, linear_attn_step,
                                     _causal_conv1d)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    conv: jax.Array    # (B, K-1, di)
    mem: jax.Array     # (B, H, N, P+1) fp32 — last column is the normalizer


def mlstm_table(cfg):
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    k = cfg.xlstm.conv1d_kernel
    return {
        "up_proj": weight((d, 2 * di), ("embed", "ff")),
        "conv_w": weight((k, di), ("conv", "ff"), stddev=0.2),
        "conv_b": bias((di,), ("ff",)),
        "wq": weight((di, h, dh), (None, "heads", None)),
        "wk": weight((di, h, dh), (None, "heads", None)),
        "wv": weight((di, h, dh), (None, "heads", None)),
        "w_i": weight((di, h), (None, "heads"), stddev=0.02),
        "b_i": bias((h,), ("heads",)),
        "w_f": weight((di, h), (None, "heads"), stddev=0.02),
        "b_f": ParamFBias((h,)),
        "skip": scale((di,), ("ff",)),
        "norm": scale((di,), ("ff",)),
        "down_proj": weight((di, d), ("ff", "embed")),
    }


def ParamFBias(shape):
    """Forget-gate bias init: positive (starts remembering), linspace [3, 6]."""
    from repro.models.layers.module import ParamDef

    def init(key, shp, dtype):
        del key
        return jnp.linspace(3.0, 6.0, shp[0]).astype(dtype)
    return ParamDef(tuple(shape), ("heads",), init)


def _mlstm_qkvg(cfg, params, x: jax.Array, conv_hist):
    """Shared projection path. x: (B,S,D)."""
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    up = jnp.einsum("...d,df->...f", x, params["up_proj"].astype(x.dtype))
    xi, z = up[..., :di], up[..., di:]
    xc, new_hist = _causal_conv1d(xi, params["conv_w"].astype(x.dtype),
                                  params["conv_b"].astype(x.dtype), conv_hist)
    xc = jax.nn.silu(xc)
    q = jnp.einsum("...f,fhk->...hk", xc, params["wq"].astype(x.dtype))
    k = jnp.einsum("...f,fhk->...hk", xc, params["wk"].astype(x.dtype)) / (dh ** 0.5)
    v = jnp.einsum("...f,fhk->...hk", xi, params["wv"].astype(x.dtype))
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("...f,fh->...h", xc, params["w_f"].astype(x.dtype))
        .astype(jnp.float32) + params["b_f"].astype(jnp.float32))
    log_i = (jnp.einsum("...f,fh->...h", xc, params["w_i"].astype(x.dtype))
             .astype(jnp.float32) + params["b_i"].astype(jnp.float32))
    log_i = jnp.clip(log_i, -30.0, 15.0)
    return q, k, v, log_f, log_i, xi, xc, z, new_hist


def _mlstm_out(cfg, params, num, den, xc, z, B, S):
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    y = num / jnp.maximum(jnp.abs(den), 1.0)                # (B,S,H,dh)
    y = y.reshape(B, S, di).astype(xc.dtype)
    y = y + params["skip"].astype(xc.dtype) * xc
    y = y.reshape(B, S, h, dh)
    # head-wise RMS norm with a full-width scale (GroupNorm analogue)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps))
    y = y.reshape(B, S, di) * params["norm"].astype(jnp.float32)
    y = y.astype(xc.dtype) * jax.nn.silu(z)
    out = jnp.einsum("...f,fd->...d", y, params["down_proj"].astype(xc.dtype))
    return constrain(out, "batch", "seq", "embed_act")


def mlstm_forward(cfg, params, x: jax.Array,
                  state: MLSTMState | None = None,
                  return_state: bool = False):
    B, S, _ = x.shape
    q, k, v, log_f, log_i, xi, xc, z, hist = _mlstm_qkvg(
        cfg, params, x, None if state is None else state.conv)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v1 = jnp.concatenate([v, ones], axis=-1)                # (B,S,H,P+1)
    y, fin = chunked_linear_attn(
        q, k, v1, log_f, log_i, chunk=128,
        initial_state=None if state is None else state.mem,
        return_final_state=True)
    num, den = y[..., :-1], y[..., -1:]
    out = _mlstm_out(cfg, params, num, den, xc, z, B, S)
    if return_state:
        return out, MLSTMState(conv=hist, mem=fin)
    return out


def mlstm_step(cfg, params, x: jax.Array, state: MLSTMState):
    """x: (B, 1, D) single-token decode."""
    B = x.shape[0]
    q, k, v, log_f, log_i, xi, xc, z, hist = _mlstm_qkvg(
        cfg, params, x, state.conv)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v1 = jnp.concatenate([v, ones], axis=-1)
    y, mem = linear_attn_step(q[:, 0], k[:, 0], v1[:, 0],
                              log_f[:, 0], log_i[:, 0], state.mem)
    y = y[:, None]                                           # (B,1,H,P+1)
    out = _mlstm_out(cfg, params, y[..., :-1], y[..., -1:], xc, z, B, 1)
    return out, MLSTMState(conv=hist, mem=mem)


def mlstm_init_state(cfg, batch: int, dtype=jnp.float32) -> MLSTMState:
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    return MLSTMState(
        conv=jnp.zeros((batch, cfg.xlstm.conv1d_kernel - 1, di), dtype),
        mem=jnp.zeros((batch, h, dh, dh + 1), jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    h: jax.Array   # (B, D) fp32
    c: jax.Array   # (B, D) fp32
    n: jax.Array   # (B, D) fp32
    m: jax.Array   # (B, D) fp32 stabilizer


def slstm_table(cfg):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dff = int(cfg.xlstm.slstm_proj_factor * d)
    return {
        # input projections for (i, f, z, o)
        "w_in": weight((d, 4, d), ("embed", None, "ff"), stddev=0.02),
        "b_in": bias((4, d), (None, "ff")),
        # head-block-diagonal recurrent weights
        "r": weight((h, dh, 4, dh), ("heads", None, None, None), stddev=0.02),
        "norm": scale((d,), ("embed",)),
        # post-cell gated MLP (proj factor 4/3)
        "up_gate": weight((d, dff), ("embed", "ff")),
        "up": weight((d, dff), ("embed", "ff")),
        "down": weight((dff, d), ("ff", "embed")),
    }


def _slstm_cell(cfg, params, wx_t: jax.Array, st: SLSTMState) -> SLSTMState:
    """One timestep. wx_t: (B, 4, D) precomputed input contribution (fp32)."""
    h_heads = st.h.reshape(st.h.shape[0], cfg.num_heads, -1)
    rh = jnp.einsum("bhk,hkgj->bghj", h_heads,
                    params["r"].astype(jnp.float32))
    pre = wx_t + rh.reshape(wx_t.shape)                      # (B,4,D)
    it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + st.m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + st.m - m_new)
    c_new = f_p * st.c + i_p * jnp.tanh(zt)
    n_new = f_p * st.n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(h=h_new, c=c_new, n=n_new, m=m_new)


def slstm_forward(cfg, params, x: jax.Array,
                  state: SLSTMState | None = None,
                  return_state: bool = False):
    """x: (B, S, D). Sequential scan over S (true recurrence)."""
    B, S, d = x.shape
    if state is None:
        state = slstm_init_state(cfg, B)
    wx = jnp.einsum("bsd,dgf->bsgf", x, params["w_in"].astype(x.dtype))
    wx = (wx + params["b_in"].astype(x.dtype)).astype(jnp.float32)

    def step(st, wx_t):
        st2 = _slstm_cell(cfg, params, wx_t, st)
        return st2, st2.h

    fin, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3))
    y = hs.transpose(1, 0, 2).astype(x.dtype)                # (B,S,D)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    g = jnp.einsum("...d,df->...f", y, params["up_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", y, params["up"].astype(x.dtype))
    h = jax.nn.gelu(g, approximate=True) * u
    h = constrain(h, "batch", "seq", "ff")
    out = jnp.einsum("...f,fd->...d", h, params["down"].astype(x.dtype))
    out = constrain(out, "batch", "seq", "embed_act")
    if return_state:
        return out, fin
    return out


def slstm_step(cfg, params, x: jax.Array, state: SLSTMState):
    out, fin = slstm_forward(cfg, params, x, state, return_state=True)
    return out, fin


def slstm_init_state(cfg, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(h=z, c=z, n=z, m=jnp.full((batch, d), -1e30, jnp.float32))
