"""Single-source-of-truth parameter tables.

A *table* is a nested dict whose leaves are :class:`ParamDef` — (shape,
logical axes, init).  From one table we derive both the initialized parameter
pytree and the logical-axis pytree used for sharding, so the two can never
drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.common import dtype_of, ones_init, truncated_normal_init, zeros_init

InitFn = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: InitFn = truncated_normal_init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def weight(shape: tuple[int, ...], axes: tuple[str | None, ...],
           stddev: float | None = None) -> ParamDef:
    if stddev is None:
        return ParamDef(tuple(shape), tuple(axes), truncated_normal_init)
    def init(key, shp, dtype, _s=stddev):
        return truncated_normal_init(key, shp, dtype, stddev=_s)
    return ParamDef(tuple(shape), tuple(axes), init)


def bias(shape: tuple[int, ...], axes: tuple[str | None, ...]) -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), zeros_init)


def scale(shape: tuple[int, ...], axes: tuple[str | None, ...]) -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), ones_init)


Table = Mapping[str, Any]  # nested dict of ParamDef


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def stack_table(table: Table, num: int) -> Table:
    """Prepend a stacked 'layers' dim to every leaf (for lax.scan)."""
    def _stack(d: ParamDef) -> ParamDef:
        def init(key, shape, dtype, _d=d):
            keys = jax.random.split(key, num)
            return jax.vmap(lambda k: _d.init(k, _d.shape, dtype))(keys)
        return ParamDef((num, *d.shape), ("layers", *d.axes), init)
    return jax.tree_util.tree_map(_stack, table, is_leaf=is_def)


def init_table(key: jax.Array, table: Table, dtype) -> Any:
    dt = dtype_of(dtype)
    leaves, treedef = jax.tree_util.tree_flatten(table, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    params = [d.init(k, d.shape, dt) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, params)


def axes_of(table: Table) -> Any:
    return jax.tree_util.tree_map(lambda d: d.axes, table, is_leaf=is_def)


def shapes_of(table: Table, dtype) -> Any:
    dt = dtype_of(dtype)
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dt), table, is_leaf=is_def)
