"""Feed-forward layers: SwiGLU (LLaMA/Qwen family) and GELU (Whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.module import bias, weight


def swiglu_table(d_model: int, d_ff: int):
    return {
        "w_gate": weight((d_model, d_ff), ("embed", "ff")),
        "w_up": weight((d_model, d_ff), ("embed", "ff")),
        "w_down": weight((d_ff, d_model), ("ff", "embed")),
    }


def swiglu(params, x: jax.Array) -> jax.Array:
    """x: (..., d_model) -> (..., d_model)."""
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate) * up
    h = constrain(h, "batch", "seq", "ff")
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))


def gelu_mlp_table(d_model: int, d_ff: int):
    return {
        "w_in": weight((d_model, d_ff), ("embed", "ff")),
        "b_in": bias((d_ff,), ("ff",)),
        "w_out": weight((d_ff, d_model), ("ff", "embed")),
        "b_out": bias((d_model,), ("embed",)),
    }


def gelu_mlp(params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(x.dtype))
    h = h + params["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, "batch", "seq", "ff")
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(x.dtype))
    return out + params["b_out"].astype(x.dtype)
