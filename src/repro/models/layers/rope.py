"""Rotary position embeddings: standard RoPE and Qwen2-VL multimodal M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponents = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return jnp.asarray(1.0 / (theta ** exponents), dtype=jnp.float32)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    # x: (..., head_dim); cos/sin broadcastable (..., head_dim//2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.

    Args:
      x: (B, S, H, D) queries or keys.
      positions: (B, S) int32 absolute positions.
      theta: rope base.
    """
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]                         # (B, S, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_m_rope(x: jax.Array, positions: jax.Array, theta: float,
                 sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    The head_dim//2 frequency slots are partitioned into `sections`
    (temporal, height, width); each section uses its own position stream.

    Args:
      x: (B, S, H, D).
      positions: (3, B, S) int32 — temporal/height/width position ids
        (identical streams for pure-text tokens).
      sections: frequency-slot counts per stream, sum == D // 2.
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    # angles per stream: (3, B, S, D/2)
    angles_all = positions[..., None].astype(jnp.float32) * freqs
    # select stream per frequency slot
    stream_id = jnp.asarray(
        np.repeat(np.arange(len(sections)), sections), dtype=jnp.int32)  # (D/2,)
    angles = jnp.take_along_axis(
        jnp.moveaxis(angles_all, 0, -1),                         # (B, S, D/2, 3)
        stream_id[None, None, :, None], axis=-1)[..., 0]         # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return _rotate(x, cos, sin)
