"""2-D conv layers for the paper's own model (GoogLeNet / Inception-v1).

NHWC layout, HWIO kernels, `lax.conv_general_dilated`.  The perf-critical
conv hot-spot has a Pallas im2col-GEMM kernel in `repro.kernels.conv2d`; this
module is the oracle and the default (XLA) path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.module import ParamDef, bias
from repro.common import truncated_normal_init


def conv_table(kh: int, kw: int, cin: int, cout: int):
    def init(key, shape, dtype):
        fan_in = kh * kw * cin
        return truncated_normal_init(key, shape, dtype,
                                     stddev=(2.0 / fan_in) ** 0.5)
    return {
        "w": ParamDef((kh, kw, cin, cout), ("conv", "conv", None, "ff"), init),
        "b": bias((cout,), ("ff",)),
    }


def conv2d(params, x: jax.Array, *, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """x: (B, H, W, Cin) -> (B, H', W', Cout)."""
    out = jax.lax.conv_general_dilated(
        x, params["w"].astype(x.dtype),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + params["b"].astype(x.dtype)


def relu_conv(params, x, *, stride=1, padding="SAME"):
    return jax.nn.relu(conv2d(params, x, stride=stride, padding=padding))


def max_pool(x: jax.Array, window: int, stride: int,
             padding: str = "SAME") -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), padding)


def avg_pool(x: jax.Array, window: int, stride: int,
             padding: str = "VALID") -> jax.Array:
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), padding)
    return s / float(window * window)


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


def lrn(x: jax.Array, *, radius: int = 2, alpha: float = 1e-4,
        beta: float = 0.75, k: float = 1.0) -> jax.Array:
    """Local response normalization across channels (AlexNet/GoogLeNet)."""
    sq = jnp.square(x.astype(jnp.float32))
    # sum over a window of 2*radius+1 channels
    pad = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (radius, radius)))
    n = sum(pad[..., i:i + x.shape[-1]] for i in range(2 * radius + 1))
    return (x.astype(jnp.float32) / jnp.power(k + alpha * n, beta)).astype(x.dtype)
