"""Token embeddings and LM heads (vocab sharded on the model axis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.module import weight


def embedding_table(vocab_size: int, d_model: int, tie: bool):
    t = {"tok": weight((vocab_size, d_model), ("vocab", "embed"), stddev=1.0)}
    if not tie:
        t["lm_head"] = weight((d_model, vocab_size), ("embed", "vocab"))
    return t


def embed(params, tokens: jax.Array, compute_dtype) -> jax.Array:
    """tokens: (B, S) int32 -> (B, S, D)."""
    out = jnp.take(params["tok"].astype(compute_dtype), tokens, axis=0)
    return constrain(out, "batch", "seq", "embed_act")


def logits(params, x: jax.Array, tie: bool,
           softcap: float = 0.0) -> jax.Array:
    """x: (..., D) -> (..., V). Computed in fp32 for numerics."""
    if tie:
        w = params["tok"].astype(jnp.float32).T
    else:
        w = params["lm_head"].astype(jnp.float32)
    out = jnp.einsum("...d,dv->...v", x.astype(jnp.float32), w)
    if softcap:
        out = softcap * jnp.tanh(out / softcap)
    return constrain(out, "batch", "seq", "vocab")
