"""Normalization layers (functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.module import bias, scale


def rmsnorm_table(dim: int, axes=("embed",)):
    return {"scale": scale((dim,), axes)}


def layernorm_table(dim: int, axes=("embed",)):
    return {"scale": scale((dim,), axes), "bias": bias((dim,), axes)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def norm_table(cfg, dim: int | None = None, axes=("embed",)):
    dim = dim or cfg.d_model
    return layernorm_table(dim, axes) if cfg.use_layernorm else rmsnorm_table(dim, axes)


def apply_norm(cfg, params, x: jax.Array) -> jax.Array:
    if cfg.use_layernorm:
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


def head_rmsnorm(scale_param, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """QK-norm: RMS-normalize the last (head) dim with a learned scale."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale_param.astype(jnp.float32)).astype(dtype)
