"""Mamba-2 (SSD) and the shared chunked linear-recurrence core.

The state-space duality view: both SSD and mLSTM compute

    H_t = exp(dA_t) * H_{t-1} + g_t * (k_t outer v_t)        (per head)
    y_t = q_t . H_t

which admits a chunkwise-parallel algorithm: quadratic attention within a
chunk + an associative scan over per-chunk states.  `chunked_linear_attn`
implements that once; Mamba-2 and mLSTM supply (q, k, v, log-decay, gate).

All recurrence math is fp32.  The matching Pallas kernel lives in
`repro.kernels.ssm_scan` with this module as its oracle.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import truncated_normal_init
from repro.distributed.sharding import constrain
from repro.models.layers.module import ParamDef, bias, scale, weight
from repro.models.layers.norms import rmsnorm


def chunked_linear_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                        log_decay: jax.Array, log_gate: jax.Array | None = None,
                        *, chunk: int = 128,
                        initial_state: jax.Array | None = None,
                        return_final_state: bool = False):
    """Chunkwise decayed linear attention (causal, inclusive of t).

    Args:
      q, k: (B, S, H, N); v: (B, S, H, P).
      log_decay: (B, S, H) log of per-step decay (<= 0 for stability).
      log_gate:  (B, S, H) log input gate applied to (k_t, v_t); None -> 0.
      initial_state: (B, H, N, P) recurrent state carried in.
    Returns:
      y (B, S, H, P) fp32 [, final_state (B, H, N, P) fp32].
    """
    B, S, H, N = k.shape
    P = v.shape[-1]
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    log_decay = log_decay.astype(jnp.float32)
    g = jnp.zeros_like(log_decay) if log_gate is None else log_gate.astype(jnp.float32)

    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v, g = map(zpad, (q, k, v, g))
        # Padded steps must be identity: decay 0 in log space, gate -inf.
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
        g = g.at[:, S:].set(-1e30)
    C = (S + pad) // chunk

    def cs(a):  # (B, S', H, ...) -> (B, C, Q, H, ...)
        return a.reshape(B, C, chunk, *a.shape[2:])

    qc, kc, vc, dc, gc = map(cs, (q, k, v, log_decay, g))
    cum = jnp.cumsum(dc, axis=2)                   # inclusive cumsum (B,C,Q,H)
    total = cum[:, :, -1]                          # (B,C,H) log chunk decay

    # ---- intra-chunk (quadratic) ----
    # w[i,j] = exp(cum_i - cum_j + g_j) for i >= j  (decay from j+1..i)
    scores = jnp.einsum("bcihn,bcjhn->bchij", qc, kc)            # (B,C,H,Q,Q)
    logw = cum.transpose(0, 1, 3, 2)[..., :, None] \
        - cum.transpose(0, 1, 3, 2)[..., None, :] \
        + gc.transpose(0, 1, 3, 2)[..., None, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(causal, jnp.exp(jnp.minimum(logw, 30.0)), 0.0)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores * w, vc)

    # ---- per-chunk summary state: S_c = sum_j exp(total - cum_j + g_j) k v^T
    wk = jnp.exp(jnp.minimum(total[:, :, None] - cum + gc, 30.0))  # (B,C,Q,H)
    s_c = jnp.einsum("bcjhn,bcjhp->bchnp", kc * wk[..., None], vc)

    # ---- inter-chunk associative scan: H_c = exp(total_c) H_{c-1} + S_c ----
    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 + a2, s1 * jnp.exp(a2)[..., None, None] + s2

    a_el = total.transpose(0, 2, 1)                               # (B,H,C)
    s_el = s_c.transpose(0, 2, 1, 3, 4)                           # (B,H,C,N,P)
    if initial_state is not None:
        a_el = jnp.concatenate([jnp.zeros_like(a_el[:, :, :1]), a_el], axis=2)
        s_el = jnp.concatenate(
            [initial_state.astype(jnp.float32)[:, :, None], s_el], axis=2)
    a_sc, h_sc = jax.lax.associative_scan(combine, (a_el, s_el), axis=2)
    if initial_state is not None:
        a_sc, h_sc = a_sc[:, :, 1:], h_sc[:, :, 1:]
    final_state = h_sc[:, :, -1]                                  # (B,H,N,P)
    # state entering chunk c is H_{c-1}
    h_prev = jnp.concatenate(
        [initial_state.astype(jnp.float32)[:, :, None] if initial_state is not None
         else jnp.zeros_like(h_sc[:, :, :1]), h_sc[:, :, :-1]], axis=2)

    # ---- inter-chunk contribution: y_off_i = exp(cum_i) q_i . H_prev ----
    wq = jnp.exp(jnp.minimum(cum, 30.0))                          # (B,C,Q,H)
    y_off = jnp.einsum("bcihn,bhcnp->bcihp", qc * wq[..., None],
                       h_prev.transpose(0, 1, 2, 3, 4))
    y = (y_diag + y_off).reshape(B, C * chunk, H, P)[:, :S]
    if return_final_state:
        return y, final_state
    return y, None


def linear_attn_step(q, k, v, log_decay, log_gate, state):
    """Single-token recurrence (decode). Shapes: q/k (B,H,N), v (B,H,P),
    log_decay/log_gate (B,H), state (B,H,N,P). Returns (y, new_state)."""
    a = jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    gate = jnp.exp(jnp.minimum(log_gate.astype(jnp.float32), 30.0))[..., None, None]
    kv = jnp.einsum("bhn,bhp->bhnp", k.astype(jnp.float32), v.astype(jnp.float32))
    new_state = a * state.astype(jnp.float32) + gate * kv
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_channels)
    ssm: jax.Array    # (B, H, N, P) fp32


def _a_log_init(key, shape, dtype):
    del key
    # A in [1, 16) log-spaced (Mamba-2 default init)
    h = shape[0]
    a = 1.0 + 15.0 * (jnp.arange(h, dtype=jnp.float32) + 0.5) / h
    return jnp.log(a).astype(dtype)


def _dt_bias_init(key, shape, dtype):
    del key
    # softplus^-1 of dt in [1e-3, 1e-1], log-spaced
    h = shape[0]
    dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), h))
    return jnp.log(jnp.expm1(dt)).astype(dtype)


def mamba_table(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    h = s.num_heads(d)
    n = s.d_state
    conv_ch = d_in + 2 * n
    return {
        # order: [z (d_in) | x (d_in) | B (n) | C (n) | dt (h)]
        "in_proj": weight((d, 2 * d_in + 2 * n + h), ("embed", "ff")),
        "conv_w": ParamDef((s.d_conv, conv_ch), ("conv", "ff"),
                           lambda k, sh, dt: truncated_normal_init(k, sh, dt, stddev=0.2)),
        "conv_b": bias((conv_ch,), ("ff",)),
        "a_log": ParamDef((h,), (None,), _a_log_init),
        "d_skip": scale((h,), (None,)),
        "dt_bias": ParamDef((h,), (None,), _dt_bias_init),
        "norm": scale((d_in,), ("ff",)),
        "out_proj": weight((d_in, d), ("ff", "embed")),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   history: jax.Array | None = None):
    """x: (B, S, Ch); w: (K, Ch) depthwise. Returns (y, new_history)."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xh = jnp.concatenate([history, x], axis=1)
    # depthwise conv as sum of shifted slices (K is tiny, typically 4)
    y = sum(xh[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    y = y + b[None, None, :]
    new_hist = xh[:, -(K - 1):, :] if K > 1 else history
    return y, new_hist


def _mamba_split(cfg, params, u: jax.Array):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    n = s.d_state
    proj = jnp.einsum("...d,df->...f", u, params["in_proj"].astype(u.dtype))
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * n]
    dt_raw = proj[..., -h:]
    return z, xbc, dt_raw, (d_in, h, n)


def mamba_forward(cfg, params, u: jax.Array,
                  state: MambaState | None = None,
                  return_state: bool = False):
    """Full-sequence Mamba-2 mixer. u: (B, S, D) -> (B, S, D)."""
    s = cfg.ssm
    B, S, D = u.shape
    z, xbc, dt_raw, (d_in, h, n) = _mamba_split(cfg, params, u)
    xbc, conv_hist = _causal_conv1d(
        xbc, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        None if state is None else state.conv)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :d_in].reshape(B, S, h, s.head_dim)
    b_in = xbc[..., d_in:d_in + n]                      # (B,S,N) single group
    c_in = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))                # (H,)
    log_decay = dt * a[None, None, :]
    x = constrain(x, "batch", "seq", "heads", None)
    log_decay = constrain(log_decay, "batch", "seq", "heads")
    # broadcast shared B/C over heads; input scaled by dt via log_gate
    kq = lambda t: constrain(
        jnp.broadcast_to(t[:, :, None, :], (B, S, h, n)),
        "batch", "seq", "heads", None)
    y, fin = chunked_linear_attn(
        kq(c_in), kq(b_in), x, log_decay, jnp.log(dt),
        chunk=s.chunk_size,
        initial_state=None if state is None else state.ssm,
        return_final_state=True)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = constrain(y, "batch", "seq", "ff")
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    out = jnp.einsum("...f,fd->...d", y, params["out_proj"].astype(u.dtype))
    out = constrain(out, "batch", "seq", "embed_act")
    if return_state:
        return out, MambaState(conv=conv_hist, ssm=fin)
    return out


def mamba_step(cfg, params, u: jax.Array, state: MambaState):
    """Single-token decode. u: (B, 1, D) -> (B, 1, D), new state."""
    s = cfg.ssm
    B = u.shape[0]
    z, xbc, dt_raw, (d_in, h, n) = _mamba_split(cfg, params, u)
    xbc, conv_hist = _causal_conv1d(
        xbc, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        state.conv)
    xbc = jax.nn.silu(xbc)
    x = xbc[:, 0, :d_in].reshape(B, h, s.head_dim)
    b_in = jnp.broadcast_to(xbc[:, 0, None, d_in:d_in + n], (B, h, n))
    c_in = jnp.broadcast_to(xbc[:, 0, None, d_in + n:], (B, h, n))
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))    # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, new_ssm = linear_attn_step(c_in, b_in, x, dt * a[None, :],
                                  jnp.log(dt), state.ssm)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    out = jnp.einsum("...f,fd->...d", y, params["out_proj"].astype(u.dtype))
    return out, MambaState(conv=conv_hist, ssm=new_ssm)


def mamba_init_state(cfg, batch: int, dtype=jnp.float32) -> MambaState:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    return MambaState(
        conv=jnp.zeros((batch, s.d_conv - 1, d_in + 2 * s.d_state), dtype),
        ssm=jnp.zeros((batch, h, s.d_state, s.head_dim), jnp.float32))
