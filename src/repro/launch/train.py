"""Training launcher.

CPU-runnable end-to-end with the reduced (smoke) configs; on a TPU fleet the
same driver runs the full configs under `make_production_mesh` (the mesh and
sharding plumbing are identical to the dry-run's).

Example (the (b) end-to-end driver — ~100M-class model, few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 300 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json

from repro.configs import registry as arch_registry
from repro.data.pipeline import Prefetcher, SyntheticTokens
from repro.distributed.fault import FaultSchedule
from repro.optim.optimizers import adamw, warmup_cosine
from repro.training.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-fault", type=int, default=None,
                    help="simulate a crash at this step (recovery demo)")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = (arch_registry.smoke(args.arch) if args.smoke
           else arch_registry.config(args.arch))
    data = Prefetcher(SyntheticTokens(cfg, args.batch, args.seq))
    faults = FaultSchedule(
        events={args.inject_fault: "crash"} if args.inject_fault else {})
    tc = TrainerConfig(num_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, iter(data), tc,
                      optimizer=adamw(warmup_cosine(args.lr, args.warmup,
                                                    args.steps)),
                      fault_schedule=faults, accum=args.accum)
    if args.resume:
        trainer.try_resume()
    history = trainer.train()
    losses = [h["loss"] for h in history if "loss" in h]
    print(f"steps={len(losses)} first_loss={losses[0]:.3f} "
          f"last_loss={losses[-1]:.3f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
