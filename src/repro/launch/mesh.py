"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2x16x16 = 512 chips, axes (pod, data,
model); the pod axis is pure data parallelism over DCN-class links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
