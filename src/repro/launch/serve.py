"""Serving launcher: continuous-batching engine with the paper's
throughput / throughput-per-watt reporting plus serving-quality metrics
(TTFT p50/p99, TPOT, slot occupancy).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 16 --new-tokens 8 --replicas 2
  # A/B against the legacy lock-step wave decode:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --mode wave
  # chunked prefill (long prompts interleave with decode steps) and the
  # seeded-prefill recompute baseline:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --prompt-len 96 --prefill-chunk 32
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --no-seeded-prefill
  # replica-router policy A/B (multi-replica only): strip prefix-affinity
  # routing and idle-replica work stealing back to least-loaded dispatch:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --replicas 2 --no-affinity --no-steal
  # speculative decoding: a drafter proposes k tokens per step, the target
  # verifies them in one batched pass — greedy outputs stay bit-identical:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --draft-model qwen2.5-3b --spec-k 3
  # disaggregated fleet: one replica prefills at full chunk budget and
  # migrates each finished prompt's KV blocks to the other, which only
  # decodes — zero prompt recompute on the decode side:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --replicas 2 --replica-roles prefill,decode --prefill-chunk 32
  # chaos run: kill one of two replicas mid-serve; its requests retry on
  # the survivor (bit-identical greedy regeneration), with per-request
  # deadlines cancelling anything that overstays:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --replicas 2 --inject-faults replica.executor:raise:4 \
      --max-retries 2 --deadline-s 30
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.router import ReplicaRouter
from repro.serving.sampler import greedy, temperature


def _fmt_ms(v: float | None) -> str:
    return f"{v * 1e3:.1f}ms" if v is not None else "n/a"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica count; >1 routes individual requests "
                         "through the ReplicaRouter (prefix-affinity + "
                         "block-aware placement, idle replicas steal "
                         "queued work)")
    ap.add_argument("--no-affinity", action="store_true",
                    help="multi-replica only: disable prefix-affinity "
                         "routing (requests place by block-aware load "
                         "alone, so identical prefixes land on arbitrary "
                         "replicas and seeded prefill only fires locally)")
    ap.add_argument("--no-steal", action="store_true",
                    help="multi-replica only: disable work stealing (an "
                         "idle replica no longer pulls queued requests "
                         "off a backlogged peer)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--contiguous-kv", action="store_true",
                    help="disable the paged KV pool (worst-case per-slot "
                         "cache, per-prompt-length prefill compiles)")
    ap.add_argument("--kv-pool-blocks", type=int, default=None,
                    help="paged KV pool size in blocks (default: worst "
                         "case = slots x ceil(max_len / block_size))")
    ap.add_argument("--no-preemption", action="store_true",
                    help="disable decode preemption (paged KV only): a "
                         "high-priority request waits for a slot/blocks "
                         "instead of evicting a lower-priority decode")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable refcounted prompt-prefix block sharing")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="C",
                    help="paged KV only: prefill prompts in C-token chunks "
                         "interleaved with decode steps (C must be a "
                         "multiple of the 16-token block size; default: "
                         "whole prompt in one go, stalling active decodes "
                         "for its full prefill)")
    ap.add_argument("--host-blocks", type=int, default=0, metavar="N",
                    help="tiered KV cache: spill cold pool blocks (idle "
                         "shared prefixes, preemption victims' histories) "
                         "to an N-block host tier and restore them "
                         "asynchronously through the split-phase offload "
                         "protocol instead of recomputing (0 = untiered)")
    ap.add_argument("--no-kv-tiering", action="store_true",
                    help="ignore --host-blocks: run the untiered pool "
                         "(the recompute A/B baseline for tiering)")
    ap.add_argument("--no-seeded-prefill", action="store_true",
                    help="recompute baseline: shared prefix blocks are "
                         "still mapped, but every prompt token is re-run "
                         "and its rows discarded into the trash block "
                         "(compare prefill_tokens_computed)")
    ap.add_argument("--hipri-every", type=int, default=0, metavar="N",
                    help="mark every Nth request priority 1 (0 = all "
                         "requests priority 0); exercises SLO-aware "
                         "admission and preemption")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT SLO attached to the high-priority requests "
                         "(reported as slo_miss_rate)")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="enable speculative decoding with this arch as "
                         "the drafter (paged KV only); greedy requests "
                         "propose --spec-k tokens per step and the target "
                         "verifies them in one batched pass — outputs are "
                         "bit-identical to vanilla greedy.  Same arch as "
                         "--arch = self-speculation (shares the target's "
                         "weights)")
    ap.add_argument("--spec-k", type=int, default=3, metavar="K",
                    help="drafter tokens proposed per speculative round "
                         "(each verify pass scores K+1 positions and "
                         "commits 1..K+1 tokens)")
    ap.add_argument("--no-spec", action="store_true",
                    help="ignore --draft-model: run vanilla decode (the "
                         "A/B baseline for speculative decoding)")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="S",
                    help="per-request completion deadline: a request "
                         "still queued or mid-decode after S seconds is "
                         "cancelled with a typed DeadlineExceeded and its "
                         "KV blocks reclaimed")
    ap.add_argument("--max-retries", type=int, default=2, metavar="N",
                    help="multi-replica only: reissue a request that "
                         "failed on one replica (poison fault, replica "
                         "crash) to a surviving replica up to N times "
                         "before marking it FAILED; retries restart from "
                         "the bare prompt, so greedy outputs stay "
                         "bit-identical")
    ap.add_argument("--replica-roles", default=None, metavar="R1,R2,...",
                    help="disaggregated fleet: comma-separated per-replica "
                         "roles (prefill/decode/mixed, one per --replicas); "
                         "prefill-role replicas migrate each finished "
                         "prompt's KV blocks to a decode-capable replica "
                         "instead of decoding locally")
    ap.add_argument("--inject-faults", default=None, metavar="PLAN",
                    help="deterministic fault injection for chaos runs: "
                         "comma-separated site[:action[:after[:count]]] "
                         "specs (sites: target.compute engine.prefill "
                         "engine.decode kv.spill kv.fetch "
                         "replica.executor; actions: raise drop delay) or "
                         "seed=<int> for a random seeded plan — e.g. "
                         "'replica.executor:raise:4,kv.fetch:drop'")
    ap.add_argument("--mode", choices=("continuous", "wave"),
                    default="continuous",
                    help="wave = legacy lock-step decode (single replica "
                         "only), for A/B comparison")
    args = ap.parse_args()
    if args.mode == "wave" and args.replicas > 1:
        ap.error("--mode wave is the single-replica legacy baseline; "
                 "drop --replicas or use --mode continuous")

    cfg = (arch_registry.smoke(args.arch) if args.smoke
           else arch_registry.config(args.arch))
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + 1
    rng = np.random.default_rng(0)
    mk_sampler = (greedy if args.temperature == 0
                  else lambda: temperature(args.temperature, top_k=40))
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens, sampler=mk_sampler())
            for i in range(args.requests)]
    if args.hipri_every:
        for r in reqs[::args.hipri_every]:
            r.priority = 1
            if args.slo_ttft_ms is not None:
                r.slo_ttft_s = args.slo_ttft_ms / 1e3
    if args.deadline_s is not None:
        for r in reqs:
            r.deadline_s = args.deadline_s
    fault_plan = (FaultPlan.parse(args.inject_faults)
                  if args.inject_faults else None)

    kw = dict(max_len=max_len, batch_slots=args.slots,
              paged=False if args.contiguous_kv else None,
              pool_blocks=args.kv_pool_blocks,
              preemption=not args.no_preemption,
              prefix_sharing=not args.no_prefix_sharing,
              prefill_chunk=args.prefill_chunk,
              seeded_prefill=not args.no_seeded_prefill,
              host_blocks=0 if args.no_kv_tiering else args.host_blocks,
              fault_plan=fault_plan)
    if args.draft_model and not args.no_spec:
        if args.contiguous_kv:
            ap.error("--draft-model needs the paged KV pool; "
                     "drop --contiguous-kv")
        if args.draft_model == args.arch:
            draft_cfg, draft_params = cfg, params   # self-speculation
        else:
            draft_cfg = (arch_registry.smoke(args.draft_model) if args.smoke
                         else arch_registry.config(args.draft_model))
            draft_params = fns_for(draft_cfg).init(draft_cfg,
                                                   jax.random.PRNGKey(1))
        kw.update(draft_cfg=draft_cfg, draft_params=draft_params,
                  spec_k=args.spec_k)
    roles = (args.replica_roles.split(",") if args.replica_roles
             else ["mixed"] * args.replicas)
    if len(roles) != args.replicas:
        ap.error(f"--replica-roles names {len(roles)} roles for "
                 f"--replicas {args.replicas}")
    if args.replicas == 1 and roles != ["mixed"]:
        ap.error("--replica-roles needs --replicas > 1 (a lone prefill "
                 "replica has nowhere to migrate blocks)")
    if args.replicas > 1:
        replicas = [ServingEngine(cfg, params, name=f"replica{i}",
                                  role=roles[i], **kw)
                    for i in range(args.replicas)]
        router = ReplicaRouter(replicas, affinity=not args.no_affinity,
                               steal=not args.no_steal,
                               max_retries=args.max_retries)
        stats = router.serve(reqs)
    else:
        eng = ServingEngine(cfg, params, **kw)
        stats = (eng.serve_wave(reqs) if args.mode == "wave"
                 else eng.serve(reqs))
    print(f"requests={stats.requests} tokens={stats.tokens} "
          f"wall={stats.wall_s:.2f}s tok/s={stats.tokens_per_s:.2f}")
    print(f"ttft p50={_fmt_ms(stats.ttft_p50_s)} "
          f"p99={_fmt_ms(stats.ttft_p99_s)}  "
          f"tpot={_fmt_ms(stats.mean_tpot_s)}  "
          f"slot_occupancy={stats.slot_occupancy:.2f}")
    if stats.kv_blocks_peak is not None:
        print(f"prefill_compiles={stats.prefill_compiles}  "
              f"kv_blocks_peak={stats.kv_blocks_peak}  "
              f"kv_pool_util={stats.kv_pool_util:.2f}")
    if stats.prefill_tokens_total:
        stall = (f"{stats.decode_stall_p99_s * 1e3:.1f}ms"
                 if stats.decode_stall_p99_s is not None else "n/a")
        print(f"prefill_tokens={stats.prefill_tokens_computed}"
              f"/{stats.prefill_tokens_total} computed "
              f"({stats.prefill_compute_frac:.0%})  "
              f"decode_stall_p99={stall}")
    if args.replicas > 1:
        print(f"router: affinity_hits={stats.router_affinity_hits}  "
              f"steals={stats.router_steals}")
    if stats.spec_proposed:
        spt = (f"{stats.steps_per_token:.2f}"
               if stats.steps_per_token is not None else "n/a")
        print(f"spec: accept_rate={stats.accept_rate:.2f}  "
              f"verify_steps={stats.verify_steps}  "
              f"decode_steps={stats.decode_steps}  steps/token={spt}")
    if stats.kv_migrations:
        print(f"disagg: migrations={stats.kv_migrations}  "
              f"migrated_blocks={stats.migrated_blocks}")
    if stats.kv_spills or stats.kv_fetches:
        hit = (f"{stats.kv_hit_rate:.2f}"
               if stats.kv_hit_rate is not None else "n/a")
        print(f"tiering: spills={stats.kv_spills}  "
              f"fetches={stats.kv_fetches}  "
              f"host_hits={stats.prefix_hits_host}  "
              f"spill_bytes={stats.spill_bytes}  kv_hit_rate={hit}")
    if (stats.requests_failed or stats.requests_retried
            or stats.replica_failures or stats.shed_rejections
            or stats.faults_injected):
        print(f"faults: injected={stats.faults_injected}  "
              f"failed={stats.requests_failed}  "
              f"retried={stats.requests_retried}  "
              f"replica_failures={stats.replica_failures}  "
              f"shed={stats.shed_rejections}")
    if stats.preemptions or stats.prefix_shared_blocks or stats.slo_tracked:
        miss = (f"{stats.slo_miss_rate:.2f}"
                if stats.slo_miss_rate is not None else "n/a")
        print(f"preemptions={stats.preemptions}  "
              f"prefix_shared_blocks={stats.prefix_shared_blocks}  "
              f"slo_miss_rate={miss}")
    report = tpu_serving_report(stats.tokens_per_s, chips=args.replicas)
    print(report.row())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
