"""Serving launcher: batched requests through the engine, with the paper's
throughput / throughput-per-watt reporting.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --requests 16 --new-tokens 8 --replicas 2
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry as arch_registry
from repro.core.power import tpu_serving_report
from repro.models.registry import fns_for
from repro.serving.engine import MultiReplicaEngine, Request, ServingEngine
from repro.serving.sampler import greedy, temperature


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = (arch_registry.smoke(args.arch) if args.smoke
           else arch_registry.config(args.arch))
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens + 1
    rng = np.random.default_rng(0)
    mk_sampler = (greedy if args.temperature == 0
                  else lambda: temperature(args.temperature, top_k=40))
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens, sampler=mk_sampler())
            for i in range(args.requests)]

    if args.replicas > 1:
        replicas = [ServingEngine(cfg, params, max_len=max_len,
                                  batch_slots=args.slots)
                    for _ in range(args.replicas)]
        stats = MultiReplicaEngine(replicas).serve(reqs,
                                                   group_size=args.slots)
    else:
        stats = ServingEngine(cfg, params, max_len=max_len,
                              batch_slots=args.slots).serve(reqs)
    print(f"requests={stats.requests} tokens={stats.tokens} "
          f"wall={stats.wall_s:.2f}s tok/s={stats.tokens_per_s:.2f}")
    report = tpu_serving_report(stats.tokens_per_s, chips=args.replicas)
    print(report.row())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
