import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real step function (train_step /
prefill / decode), lowers it against ShapeDtypeStruct stand-ins with the
cell's sharding policy, compiles for the production mesh, and records:

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``    — XLA's per-iteration FLOPs/bytes
  * parsed-HLO totals (trip-count-corrected FLOPs, fusion-boundary bytes,
    per-kind collective bytes)      — inputs to EXPERIMENTS.md §Roofline

Artifacts land in ``artifacts/dryrun/<cell>.json``.  Any failure here
(sharding mismatch, OOM at compile, unsupported collective) is a bug in the
framework, not in the run.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import registry as arch_registry
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.specs import abstract_params, input_specs
from repro.distributed import policy
from repro.distributed.sharding import rules_for, use_rules
from repro.launch.mesh import make_production_mesh
from repro.models.registry import fns_for
from repro.optim.optimizers import make_optimizer
from repro.roofline.hlo_parse import analyze_hlo
from repro.training.train_step import make_train_step
from repro.distributed.sharding import active_param_count, param_count


def build_lowerable(cfg, shape, mesh, rules, *, overrides=None):
    """Returns (fn, jit_kwargs, abstract_args) for the cell's step."""
    fns = fns_for(cfg)
    ov = overrides or {}
    p_sh = policy.param_shardings(cfg, mesh, rules)
    p_sds = abstract_params(cfg)
    cache_dtype = ov.get("cache_dtype", "bfloat16")
    batch_specs, state_specs = input_specs(cfg, shape, cache_dtype)
    b_sh = policy.batch_shardings(batch_specs, mesh, rules)
    chunk = ov.get("chunk", {"train": 4096, "prefill": 2048,
                             "decode": 1024}[shape.kind])

    if shape.kind == "train":
        optimizer = make_optimizer(cfg)
        step = make_train_step(cfg, optimizer,
                               accum=ov.get("accum", cfg.accum_steps),
                               chunk=chunk)
        o_sds = jax.eval_shape(optimizer.init, p_sds)
        o_sh = policy.opt_state_shardings(cfg, optimizer, mesh, rules)
        return (step,
                dict(in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1)),
                (p_sds, o_sds, batch_specs))

    if shape.kind == "prefill":
        s_sh = policy.decode_state_shardings(cfg, mesh, rules)

        def step(params, batch):
            return fns.prefill(cfg, params, batch, max_len=shape.seq_len,
                               chunk=chunk)
        return (step,
                dict(in_shardings=(p_sh, b_sh),
                     out_shardings=(None, s_sh)),
                (p_sds, batch_specs))

    if shape.kind == "decode":
        s_sh = policy.decode_state_shardings(cfg, mesh, rules, cache_dtype)
        t_sh = b_sh["tokens"]

        def step(params, tokens, state):
            return fns.decode(cfg, params, tokens, state,
                              chunk=ov.get("decode_chunk", 2048))
        return (step,
                dict(in_shardings=(p_sh, t_sh, s_sh),
                     out_shardings=(None, s_sh),
                     donate_argnums=(2,)),
                (p_sds, batch_specs["tokens"], state_specs))

    raise ValueError(shape.kind)


def exact_param_counts(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the real abstract param tree
    (the closed-form estimate in `sharding` is transformer-specific)."""
    import numpy as np
    leaves = jax.tree_util.tree_leaves(abstract_params(cfg))
    total = int(sum(np.prod(l.shape) for l in leaves))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        moe_layers = cfg.num_layers - m.first_k_dense
        routed = moe_layers * 3 * cfg.d_model * m.d_ff_expert
        active = total - routed * (m.num_experts - m.top_k)
    return total, active


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the step: 6*N_active*D (train),
    2*N_active*D (inference), D = tokens processed."""
    _, n = exact_param_counts(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, *, overrides=None,
             verbose: bool = True) -> dict:
    assignment = arch_registry.get(arch)
    cfg = assignment.model
    shape = SHAPES_BY_NAME[shape_name]
    ov = overrides or {}
    if ov.get("remat"):
        cfg = cfg.replace(remat=ov["remat"])
    if ov.get("capacity_factor") and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=ov["capacity_factor"]))
    if ov.get("param_dtype"):
        cfg = cfg.replace(param_dtype=ov["param_dtype"])
    if shape_name in assignment.skipped:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "SKIP", "reason": assignment.skipped[shape_name]}
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}__{shape_name}__{rec['mesh']}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: SKIP "
                  f"({assignment.skipped[shape_name][:60]}...)")
        return rec

    if shape.kind != "train":
        # Serving runs reduced precision (the paper's VPU-FP16 theme -> bf16
        # on TPU): weights are cast once at load time.
        cfg = cfg.replace(param_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, shape, mesh, **{
        k: v for k, v in (overrides or {}).items()
        if k in ("fsdp", "seq_shard_kv")})
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "devices": mesh.devices.size, "kind": shape.kind}
    try:
        fn, jit_kwargs, args = build_lowerable(cfg, shape, mesh, rules,
                                               overrides=overrides)
        with mesh, use_rules(rules, mesh):
            t0 = time.time()
            lowered = jax.jit(fn, **jit_kwargs).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze_hlo(compiled.as_text())
        n_dev = mesh.devices.size
        mf = model_flops(cfg, shape)
        # analytic per-device state bytes (exact; CPU legalization-free)
        analytic = {}
        try:
            p_sh = policy.param_shardings(cfg, mesh, rules)
            p_sds = abstract_params(cfg)
            analytic["param_bytes_per_device"] = \
                policy.sharded_bytes_per_device(p_sds, p_sh, mesh)
            if shape.kind == "train":
                optimizer = make_optimizer(cfg)
                o_sds = jax.eval_shape(optimizer.init, p_sds)
                o_sh = policy.opt_state_shardings(cfg, optimizer, mesh, rules)
                analytic["opt_bytes_per_device"] = \
                    policy.sharded_bytes_per_device(o_sds, o_sh, mesh)
            if shape.kind == "decode":
                _, st = input_specs(cfg, shape)
                s_sh = policy.decode_state_shardings(cfg, mesh, rules)
                analytic["state_bytes_per_device"] = \
                    policy.sharded_bytes_per_device(st, s_sh, mesh)
        except Exception as e:   # noqa: BLE001
            analytic["error"] = str(e)
        rec.update({
            "status": "OK",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_per_device": (ma.argument_size_in_bytes
                                          + ma.output_size_in_bytes
                                          + ma.temp_size_in_bytes
                                          - ma.alias_size_in_bytes),
                # NOTE: the CPU backend legalizes bf16 ops via f32 converts
                # (FloatNormalization), so temp_bytes over-reports the TPU
                # target by up to 2x on bf16-heavy programs; `analytic` holds
                # legalization-free state byte counts.
                "analytic": analytic,
            },
            "xla_cost": {"flops_per_iter": ca.get("flops", 0.0),
                         "bytes_per_iter": ca.get("bytes accessed", 0.0)},
            "hlo": {
                "flops_per_device": hlo.flops,
                "dot_flops_per_device": hlo.dot_flops,
                "bytes_per_device": hlo.bytes_fused,
                "bytes_per_device_cpu_bound": hlo.bytes_accessed,
                "collective_operand_bytes": hlo.collective_operand_bytes,
                "collective_out_bytes": hlo.collective_out_bytes,
                "collective_ring_bytes": hlo.collective_ring_bytes,
                "collectives": hlo.collective_summary(),
                "while_trips": hlo.while_trips,
            },
            "model": {
                "params": exact_param_counts(cfg)[0],
                "active_params": exact_param_counts(cfg)[1],
                "model_flops_global": mf,
                "useful_flops_ratio": (
                    mf / (hlo.flops * n_dev) if hlo.flops else None),
            },
        })
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: OK "
                  f"compile={rec['compile_s']}s "
                  f"peak/device={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"hlo_flops/dev={hlo.flops:.3g} "
                  f"coll_ring={hlo.collective_ring_bytes/2**20:.1f}MiB")
            print("  memory_analysis:", ma)
            print("  cost_analysis: flops/iter=%.4g bytes/iter=%.4g"
                  % (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
    except Exception as e:   # noqa: BLE001 — record and continue
        rec.update({"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAIL {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--accum", type=int, default=None,
                    help="override grad-accum microbatches (train cells)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="override attention KV-chunk size")
    ap.add_argument("--cache-dtype", default=None,
                    choices=("bfloat16", "int8"),
                    help="KV-cache dtype for decode cells")
    ap.add_argument("--remat", default=None, choices=("none", "full", "dots"))
    args = ap.parse_args()
    overrides = {k: v for k, v in (("accum", args.accum),
                                   ("chunk", args.chunk),
                                   ("cache_dtype", args.cache_dtype),
                                   ("remat", args.remat)) if v is not None}

    archs = list(arch_registry.ARCH_IDS) if (args.all or not args.arch) \
        else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        assignment = arch_registry.get(arch)
        shapes = [args.shape] if args.shape else list(assignment.shapes)
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, overrides=overrides)
                n_fail += rec["status"] == "FAIL"
    print("FAILURES:", n_fail)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
