"""Post-SPMD HLO text analysis with loop trip-count accounting.

`compiled.cost_analysis()` on the CPU backend visits each `while` body ONCE
(no trip-count multiplication), which under-counts scanned layer stacks by
~L x.  This parser walks the computation graph from ENTRY, multiplies while
bodies by their trip counts (recovered from the canonical `constant(N)` in
the loop condition), resolves fusion/call subcomputations for FLOP counting,
and models bytes at fusion boundaries (operands + outputs of top-level ops
= HBM traffic).

Collectives are recorded with operand/output bytes, op kind, shard-group
size, and execution count, giving both the assignment's operand-bytes sum
and a ring-traffic model.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|"
    r"s64|u64|c64|c128)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^()]*\)|\S+)\s+([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "and", "or", "xor", "not", "compare", "select", "clamp", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "expm1", "log1p",
    "remainder", "atan2", "cbrt", "round-nearest-afz", "erf",
    "round-nearest-even", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "convert", "exponential-minus-one",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "all-gather-start", "all-reduce-start")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    rest: str
    operands: list[str] = field(default_factory=list)


@dataclass
class CollectiveRecord:
    kind: str
    out_bytes: int
    operand_bytes: int
    group_size: int
    count: int          # execution count (trip-multiplied)

    @property
    def ring_bytes(self) -> float:
        """Per-chip link traffic under ring algorithms."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        if self.kind.startswith("all-reduce"):
            return 2 * (n - 1) / n * self.out_bytes
        if self.kind.startswith("all-gather"):
            return (n - 1) / n * self.out_bytes
        if self.kind == "reduce-scatter":
            return (n - 1) / n * self.operand_bytes
        if self.kind == "all-to-all":
            return (n - 1) / n * self.out_bytes
        if self.kind == "collective-permute":
            return float(self.out_bytes)
        return float(self.out_bytes)


@dataclass
class HloCost:
    flops: float = 0.0               # per-device, trip-multiplied
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0      # CPU-fusion-boundary model (upper bound)
    # TPU model: standalone elementwise/shape ops fuse into their producers
    # (the CPU backend leaves them unfused + f32-legalized), so only dots,
    # fusions, slicing/update traffic, reduces, and collectives touch HBM.
    bytes_fused: float = 0.0
    collectives: list[CollectiveRecord] = field(default_factory=list)
    while_trips: dict = field(default_factory=dict)

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes * c.count for c in self.collectives)

    @property
    def collective_out_bytes(self) -> float:
        return sum(c.out_bytes * c.count for c in self.collectives)

    @property
    def collective_ring_bytes(self) -> float:
        return sum(c.ring_bytes * c.count for c in self.collectives)

    def collective_summary(self) -> dict:
        agg: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0.0,
                                                    "ring_bytes": 0.0})
        for c in self.collectives:
            a = agg[c.kind]
            a["count"] += c.count
            a["bytes"] += c.out_bytes * c.count
            a["ring_bytes"] += c.ring_bytes * c.count
        return dict(agg)


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._shape_cache: dict[tuple[str, str], str] = {}

    def _parse(self, text: str) -> None:
        cur: list[Instr] | None = None
        cur_name = None
        for line in text.splitlines():
            if line.startswith("}"):
                cur = None
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and not _INSTR_RE.match(line):
                cur_name = hdr.group(2)
                cur = []
                self.computations[cur_name] = cur
                if hdr.group(1):
                    self.entry = cur_name
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rest = m.group(3)
            opm = _OPCODE_RE.match(rest)
            if not opm:
                continue
            opcode = opm.group(1)
            out_type = rest[:opm.start(1)].strip()
            paren = rest[opm.end(1):]
            depth = 0
            args = ""
            for ch in paren:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    args += ch
            operands = _OPERAND_RE.findall(args)
            cur.append(Instr(name=m.group(2), opcode=opcode,
                             out_type=out_type, rest=rest,
                             operands=operands))

    # -- shape lookup ---------------------------------------------------------

    def _operand_type(self, comp: str, name: str) -> str:
        key = (comp, name)
        if key in self._shape_cache:
            return self._shape_cache[key]
        for ins in self.computations.get(comp, ()):
            if ins.name == name:
                self._shape_cache[key] = ins.out_type
                return ins.out_type
        self._shape_cache[key] = ""
        return ""

    # -- trip counts ------------------------------------------------------------

    def _trip_count(self, cond_comp: str) -> int:
        consts = []
        for ins in self.computations.get(cond_comp, ()):
            consts += [int(c) for c in _CONST_RE.findall(ins.rest)]
        return max(consts) if consts else 1

    # -- cost walk ----------------------------------------------------------------

    def cost(self) -> HloCost:
        out = HloCost()
        assert self.entry, "no ENTRY computation"
        self._walk(self.entry, 1, out, top_level=True)
        return out

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = 1
        for d in _shape_dims(ins.out_type):
            out_elems *= d
        lhs_t = self._operand_type(comp, ins.operands[0]) if ins.operands else ""
        lhs_dims = _shape_dims(lhs_t)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        contract = 1
        if m and lhs_dims:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= lhs_dims[int(idx)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        out_elems = 1
        for d in _shape_dims(ins.out_type):
            out_elems *= d
        # kernel operand: spatial window x input features x 2
        rhs_t = self._operand_type(comp, ins.operands[1]) \
            if len(ins.operands) > 1 else ""
        rdims = _shape_dims(rhs_t)
        k = 1
        for d in rdims[:-1]:   # HWIO: all but output features
            k *= d
        return 2.0 * out_elems * k

    def _flops_of(self, comp: str, counted: set) -> tuple[float, float]:
        """(total flops, dot flops) of one computation, recursing into
        fusions/calls (NOT whiles — handled by _walk)."""
        if comp in counted:
            pass  # computations may be shared; cost per invocation is correct
        total = 0.0
        dots = 0.0
        for ins in self.computations.get(comp, ()):
            if ins.opcode == "dot":
                f = self._dot_flops(comp, ins)
                total += f
                dots += f
            elif ins.opcode == "convolution":
                f = self._conv_flops(comp, ins)
                total += f
                dots += f
            elif ins.opcode in ("fusion", "call", "custom-call"):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    t, d = self._flops_of(m.group(1), counted)
                    total += t
                    dots += d
            elif ins.opcode in ("reduce", "reduce-window"):
                elems = 1
                t = self._operand_type(comp, ins.operands[0]) \
                    if ins.operands else ins.out_type
                for d in _shape_dims(t):
                    elems *= d
                total += elems
            elif ins.opcode in _ELEMENTWISE:
                elems = 1
                for d in _shape_dims(ins.out_type):
                    elems *= d
                total += elems
        return total, dots

    def _fusion_param_bytes(self, called: str):
        """(per-param charges, output-charge override | None) for a fused
        computation.  Two in-place patterns matter for scanned stacks:
        parameters consumed only through slicing ops are charged the slice,
        and a root dynamic-update-slice aliases its buffer param — traffic
        is 2x the updated slice, not the whole buffer."""
        key = ("__fparams__", called)
        if key in self._shape_cache:
            return self._shape_cache[key]
        instrs = self.computations.get(called, ())
        params: dict[int, tuple[str, str]] = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.rest)
                if m:
                    params[int(m.group(1))] = (ins.name, ins.out_type)
        # in-place DUS root: find a DUS whose output is fusion-output-sized
        dus = [i for i in instrs if i.opcode == "dynamic-update-slice"]
        out_override = None
        dus_buffers: set[str] = set()
        if dus:
            upd_bytes = 0.0
            for d in dus:
                if len(d.operands) > 1:
                    upd_bytes += float(_shape_bytes(
                        self._operand_type(called, d.operands[1])))
                if d.operands:
                    dus_buffers.add(d.operands[0])
            out_override = 2.0 * upd_bytes
        charges: dict[int, float] = {}
        for idx, (pname, ptype) in params.items():
            full = float(_shape_bytes(ptype))
            users = [i for i in instrs if pname in i.operands]
            if pname in dus_buffers and all(
                    u.opcode in ("dynamic-update-slice", "bitcast")
                    for u in users):
                charges[idx] = 0.0   # aliased in-place buffer
            elif users and all(u.opcode in ("dynamic-slice", "slice",
                                            "gather", "bitcast", "reshape")
                               for u in users):
                charged = sum(float(_shape_bytes(u.out_type)) for u in users
                              if u.opcode in ("dynamic-slice", "slice",
                                              "gather"))
                charges[idx] = min(full, charged if charged else full)
            else:
                charges[idx] = full
        self._shape_cache[key] = (charges, out_override)
        return charges, out_override

    def _fusion_dot_bytes(self, called: str) -> float:
        """Operand+output bytes of dot/convolution ops inside a fusion."""
        key = ("__fdots__", called)
        if key in self._shape_cache:
            return self._shape_cache[key]
        total = 0.0
        for ins in self.computations.get(called, ()):
            if ins.opcode in ("dot", "convolution"):
                total += float(_shape_bytes(ins.out_type))
                for op in ins.operands:
                    total += float(_shape_bytes(
                        self._operand_type(called, op)))
            elif ins.opcode in ("fusion", "call"):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    total += self._fusion_dot_bytes(m.group(1))
        self._shape_cache[key] = total
        return total

    def _bytes_of_instr(self, comp: str, ins: Instr) -> float:
        # dtype converts are CPU float-normalization artifacts (bf16 ops get
        # wrapped in f32 converts); on the TPU target they fuse into their
        # producer/consumer, so they carry no HBM traffic of their own.
        if ins.opcode == "convert":
            return 0.0
        if ins.opcode == "copy":
            return float(_shape_bytes(ins.out_type))
        # Slicing ops touch only the slice, not the buffer they index into
        # (counting the full operand would charge scanned stacks L times).
        if ins.opcode in ("dynamic-slice", "slice", "gather"):
            return 2.0 * _shape_bytes(ins.out_type)
        if ins.opcode == "dynamic-update-slice":
            upd = (_shape_bytes(self._operand_type(comp, ins.operands[1]))
                   if len(ins.operands) > 1 else 0)
            return 2.0 * upd
        if ins.opcode == "scatter":
            upd = (_shape_bytes(self._operand_type(comp, ins.operands[2]))
                   if len(ins.operands) > 2 else _shape_bytes(ins.out_type))
            return 2.0 * upd
        if ins.opcode in ("fusion", "call"):
            m = _CALLS_RE.search(ins.rest)
            if m:
                charges, out_override = self._fusion_param_bytes(m.group(1))
                b = (out_override if out_override is not None
                     else float(_shape_bytes(ins.out_type)))
                for i, op in enumerate(ins.operands):
                    b += charges.get(
                        i, float(_shape_bytes(self._operand_type(comp, op))))
                return b
        b = _shape_bytes(ins.out_type)
        for op in ins.operands:
            b += _shape_bytes(self._operand_type(comp, op))
        return float(b)

    def _walk(self, comp: str, mult: int, out: HloCost,
              top_level: bool) -> None:
        for ins in self.computations.get(comp, ()):
            op = ins.opcode
            if op == "while":
                body_m = _CALLS_RE.search(ins.rest)
                cond_m = _COND_RE.search(ins.rest)
                trips = self._trip_count(cond_m.group(1)) if cond_m else 1
                out.while_trips[ins.name] = trips
                if body_m:
                    self._walk(body_m.group(1), mult * trips, out,
                               top_level=True)
                continue
            if op == "conditional":
                for m in re.finditer(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)([^,}]+)", ins.rest):
                    self._walk(m.group(1).strip("%"), mult, out,
                               top_level=True)
                continue
            if op.endswith("-done"):
                continue   # async completion of an already-counted *-start
            if op.startswith(COLLECTIVES) or op in COLLECTIVES:
                grp = 1
                g = _GROUPS_RE.search(ins.rest)
                if g:
                    grp = int(g.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(ins.rest)
                    if gl:
                        grp = len(gl.group(1).split(","))
                operand_b = sum(
                    _shape_bytes(self._operand_type(comp, o))
                    for o in ins.operands)
                out.collectives.append(CollectiveRecord(
                    kind=op.replace("-start", ""),
                    out_bytes=_shape_bytes(ins.out_type),
                    operand_bytes=operand_b, group_size=grp, count=mult))
                b = self._bytes_of_instr(comp, ins) * mult
                out.bytes_accessed += b
                out.bytes_fused += b
                continue
            if op in ("fusion", "call", "custom-call"):
                t, d = 0.0, 0.0
                m = _CALLS_RE.search(ins.rest)
                if m:   # flops from the called computation
                    t, d = self._flops_of(m.group(1), set())
                out.flops += t * mult
                out.dot_flops += d * mult
                out.bytes_accessed += self._bytes_of_instr(comp, ins) * mult
                # fused model: interior elementwise fuses into neighboring
                # dots (whose operands are charged in full); only in-place
                # scan-carry updates (root DUS) represent irreducible traffic
                if m:
                    _, ovr = self._fusion_param_bytes(m.group(1))
                    if ovr is not None:
                        out.bytes_fused += ovr * mult
                    # dot/conv INSIDE the fusion: charge their shapes
                    if d:
                        out.bytes_fused += self._fusion_dot_bytes(
                            m.group(1)) * mult
                continue
            if op == "dot":
                f = self._dot_flops(comp, ins)
                out.flops += f * mult
                out.dot_flops += f * mult
                b = self._bytes_of_instr(comp, ins) * mult
                out.bytes_accessed += b
                out.bytes_fused += b
                continue
            if op == "convolution":
                f = self._conv_flops(comp, ins)
                out.flops += f * mult
                out.dot_flops += f * mult
                b = self._bytes_of_instr(comp, ins) * mult
                out.bytes_accessed += b
                out.bytes_fused += b
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id"):
                continue
            if op in _ELEMENTWISE or op in (
                    "reduce", "reduce-window", "broadcast", "reshape",
                    "transpose", "copy", "iota", "concatenate", "slice",
                    "dynamic-slice", "dynamic-update-slice", "pad", "gather",
                    "scatter", "select-and-scatter", "sort", "rng",
                    "rng-bit-generator", "cholesky", "triangular-solve"):
                b = self._bytes_of_instr(comp, ins) * mult
                out.bytes_accessed += b
                if op in _ELEMENTWISE:
                    elems = 1
                    for dd in _shape_dims(ins.out_type):
                        elems *= dd
                    out.flops += elems * mult
                    # elementwise fuses into its producer on TPU: 0 bytes
                elif op in ("reduce", "reduce-window", "sort"):
                    t = self._operand_type(comp, ins.operands[0]) \
                        if ins.operands else ins.out_type
                    elems = 1
                    for dd in _shape_dims(t):
                        elems *= dd
                    out.flops += elems * mult
                    out.bytes_fused += b
                elif op in ("broadcast", "reshape", "iota", "pad"):
                    pass   # fuse / bitcast on TPU
                else:
                    out.bytes_fused += b
                continue
            # unknown op: count bytes conservatively
            b = self._bytes_of_instr(comp, ins) * mult
            out.bytes_accessed += b
            out.bytes_fused += b


def analyze_hlo(text: str) -> HloCost:
    return HloModule(text).cost()
