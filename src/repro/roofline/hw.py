"""Hardware constants for roofline analysis and the power model.

The TARGET platform is a TPU v5e pod (this container is a CPU host used only
for lowering/compiling).  The paper's devices are kept alongside so the
paper-reproduction benchmarks (Fig 6/8) can report the same TDP-normalized
metrics the paper uses.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    ici_link_bandwidth: float   # bytes/s per link
    ici_links: int              # links per chip (torus degree)
    hbm_bytes: float            # HBM capacity per chip
    vmem_bytes: float           # on-chip scratchpad (VMEM / CMX analogue)
    tdp_watts: float            # thermal design power per chip


# Assignment-specified constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,                 # 2D torus: 4 links/chip
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    tdp_watts=200.0,
)

# The paper's co-processor: Movidius Myriad 2 VPU (MA2450) on the NCS.
# 12 SHAVEs @600MHz; manufacturer-claimed 1000 Gflops FP16; CMX 2MB; TDP 0.9W
# (2.5W peak for the whole NCS stick).
MYRIAD2_VPU = ChipSpec(
    name="myriad2-vpu",
    peak_flops_bf16=1e12,        # FP16 claimed peak
    hbm_bandwidth=4e9,           # LPDDR3 ballpark
    ici_link_bandwidth=0.4e9,    # USB 3.0 effective
    ici_links=1,
    hbm_bytes=4 * 1024**3,       # 4GB stacked LPDDR3
    vmem_bytes=2 * 1024**2,      # CMX
    tdp_watts=0.9,
)

NCS_STICK_PEAK_WATTS = 2.5       # whole-stick peak per the paper

# Reference devices from the paper's evaluation (TDP only is used).
XEON_E5_2609V2 = ChipSpec(
    name="xeon-e5-2609v2",
    peak_flops_bf16=80e9 * 4,    # 4 cores @2.5GHz, AVX fp32-ish; not used for roofline
    hbm_bandwidth=51.2e9,
    ici_link_bandwidth=8e9,
    ici_links=1,
    hbm_bytes=72 * 1024**3,
    vmem_bytes=10 * 1024**2,
    tdp_watts=80.0,
)
QUADRO_K4000 = ChipSpec(
    name="quadro-k4000",
    peak_flops_bf16=1.246e12,
    hbm_bandwidth=134e9,
    ici_link_bandwidth=8e9,
    ici_links=1,
    hbm_bytes=3 * 1024**3,
    vmem_bytes=0.5 * 1024**2,
    tdp_watts=80.0,
)

CHIPS = {c.name: c for c in (TPU_V5E, MYRIAD2_VPU, XEON_E5_2609V2, QUADRO_K4000)}


def bisection_bandwidth(chip: ChipSpec, num_chips: int) -> float:
    """Aggregate ICI bandwidth available to one chip for collectives (bytes/s).

    For ring-based collectives on a torus, each chip drives ``ici_links`` links
    concurrently; the assignment's collective term divides total collective
    bytes by chips x link_bw, so we expose per-chip link bandwidth directly.
    """
    del num_chips
    return chip.ici_link_bandwidth * chip.ici_links
