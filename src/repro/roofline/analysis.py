"""Three-term roofline from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the compiled artifact:

  compute_s    = HLO_FLOPs_per_device / peak_FLOP/s          (per chip)
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = collective_bytes_per_device / (links x link_bw)

The assignment's canonical formulation divides *global* quantities by
(chips x per-chip rate); our artifacts store per-device quantities from the
SPMD program, which is the same number (global = per-device x chips).  Two
collective accountings are kept: the assignment's operand-bytes sum and a
ring-traffic model (2(n-1)/n for all-reduce etc.) — the ring number is what
the step time actually sees and is what §Perf iterates on.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from repro.roofline.hw import TPU_V5E, ChipSpec


@dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    collective_operand_s: float
    model_flops: float
    hlo_flops_global: float
    peak_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops / self.hlo_flops_global
                if self.hlo_flops_global else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs / (chips x peak x bound time).

        The perfectly-overlapped model: the step cannot finish faster than
        its slowest roofline term; the fraction is how much useful compute
        that bound leaves on the table."""
        cap = self.devices * TPU_V5E.peak_flops_bf16 * self.bound_s
        return self.model_flops / cap if cap else 0.0


def from_record(rec: dict, chip: ChipSpec = TPU_V5E) -> Roofline:
    h = rec["hlo"]
    links_bw = chip.ici_link_bandwidth * chip.ici_links
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        devices=rec["devices"],
        compute_s=h["flops_per_device"] / chip.peak_flops_bf16,
        memory_s=h["bytes_per_device"] / chip.hbm_bandwidth,
        collective_s=h["collective_ring_bytes"] / links_bw,
        collective_operand_s=h["collective_operand_bytes"] / links_bw,
        model_flops=rec["model"]["model_flops_global"],
        hlo_flops_global=h["flops_per_device"] * rec["devices"],
        peak_bytes=rec["memory"]["peak_bytes_per_device"],
    )


def load_records(art_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def improvement_hint(r: Roofline) -> str:
    """One sentence on what would move the dominant term down."""
    if r.dominant == "collective":
        return ("cut TP activation all-reduces (sequence-parallel regions / "
                "bf16 payloads) or shard further so per-device collective "
                "bytes drop")
    if r.dominant == "memory":
        return ("fuse reads (flash-style blocks), shrink cache dtype "
                "(bf16->int8 KV), or raise arithmetic intensity with bigger "
                "per-device tiles")
    return ("reduce recompute (remat policy), skip masked work (causal "
            "block skipping), or trade batch for fewer accumulation steps")


def table(recs: list[dict], *, mesh: str = "single") -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    rows = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL/HLO flops | roofline frac | peak GiB/dev |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for rec in recs:
        if rec.get("status") == "SKIP":
            if rec["mesh"] == mesh:
                rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — "
                            f"| SKIP | — | — | — |")
            continue
        if rec.get("status") != "OK" or rec["mesh"] != mesh:
            continue
        r = from_record(rec)
        rows.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | {r.memory_s:.4f} "
            f"| {r.collective_s:.4f} | **{r.dominant}** "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.3f} "
            f"| {r.peak_bytes/2**30:.1f} |")
    return "\n".join(rows)
