"""Kernel-registry cross-check.

Every kernel family under ``kernels/<family>/`` must:

1. have an ``ops.py`` that registers at least one kernel via
   ``register_kernel(...)`` imported from ``kernels/dispatch.py`` (the
   single registry — a family registering around it would be invisible
   to ``kernel_table()`` consumers), and
2. have every registered kernel name covered by an entry in
   ``benchmarks/kernel_bench.py``'s ``COVERAGE`` table, so the smoke
   gate actually exercises it.

``kernel_bench --smoke`` already cross-checks registration↔coverage at
*runtime*; this lifts it to lint so an unregistered or uncovered kernel
fails before anything is imported, and catches stale COVERAGE entries
whose kernel was deleted.
"""
from __future__ import annotations

import ast

from .config import AnalysisConfig
from .core import Finding, dict_literal_keys, load_module


def check_kernels(cfg: AnalysisConfig) -> list[Finding]:
    findings: list[Finding] = []
    if not cfg.kernels_dir or not cfg.kernel_bench:
        return findings
    kdir = cfg.resolve(cfg.kernels_dir)
    bench = cfg.resolve(cfg.kernel_bench)
    if not kdir.is_dir() or not bench.is_file():
        return findings

    # COVERAGE keys from the bench module
    bmod = load_module(bench, cfg.repo_root)
    coverage: set[str] = set()
    cov_line = 0
    for node in bmod.tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "COVERAGE":
                    coverage = set(dict_literal_keys(node.value))
                    cov_line = node.lineno

    registered: dict[str, tuple[str, int]] = {}  # name -> (rel, line)
    for fam in sorted(p for p in kdir.iterdir() if p.is_dir()):
        if fam.name.startswith("_"):
            continue
        ops = fam / "ops.py"
        if not ops.exists():
            findings.append(Finding(
                checker="kernels", path=f"{cfg.kernels_dir}/{fam.name}",
                line=0, rule="no-ops-module", scope=fam.name,
                message=f"kernel family '{fam.name}' has no ops.py — "
                        f"nothing registers it in the dispatch table"))
            continue
        mod = load_module(ops, cfg.repo_root)
        imports_dispatch = any(
            isinstance(n, ast.ImportFrom) and n.module
            and n.module.endswith("dispatch")
            and any(a.name == "register_kernel" for a in n.names)
            for n in ast.walk(mod.tree))
        names_here = []
        for sub in ast.walk(mod.tree):
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Name) and \
                    sub.func.id == "register_kernel" and sub.args and \
                    isinstance(sub.args[0], ast.Constant):
                name = sub.args[0].value
                names_here.append(name)
                registered[name] = (mod.rel, sub.lineno)
        if not names_here:
            findings.append(Finding(
                checker="kernels", path=mod.rel, line=1,
                rule="unregistered-family", scope=fam.name,
                message=f"kernel family '{fam.name}' ops.py makes no "
                        f"register_kernel(...) call"))
        elif not imports_dispatch:
            findings.append(Finding(
                checker="kernels", path=mod.rel, line=1,
                rule="no-dispatch-import", scope=fam.name,
                message=f"'{fam.name}' registers kernels without "
                        f"importing register_kernel from "
                        f"kernels/dispatch.py — not the shared registry"))

    bench_rel = bmod.rel
    for name in sorted(set(registered) - coverage):
        rel, line = registered[name]
        findings.append(Finding(
            checker="kernels", path=rel, line=line,
            rule="uncovered-kernel", scope=name,
            message=f"kernel '{name}' is registered but has no COVERAGE "
                    f"entry in {bench_rel} — the smoke gate never "
                    f"exercises it"))
    for name in sorted(coverage - set(registered)):
        findings.append(Finding(
            checker="kernels", path=bench_rel, line=cov_line,
            rule="stale-coverage", scope=name,
            message=f"COVERAGE entry '{name}' matches no registered "
                    f"kernel"))
    return findings
