"""Repo-invariant lint pass (``python -m repro.analysis``).

Stdlib-only AST checkers proving the serving stack's hand-maintained
invariants at lint time: lock discipline (``locks``), refcount and
generation safety across block free/realloc (``refgen``), ServeStats
merge coverage (``stats``), jit trace purity and compile-cache shape
bucketing (``jit``), and the kernel registry↔smoke-coverage
cross-check (``kernels``).  See each checker module's docstring for
the precise rules and the annotation vocabulary in :mod:`.core`.
"""
from __future__ import annotations

from pathlib import Path

from .baseline import apply_baseline, load_baseline, write_baseline
from .config import AnalysisConfig, repo_config
from .core import Finding
from .faultok import check_faultok
from .jitpure import check_jit
from .kernelreg import check_kernels
from .locks import check_locks
from .refgen import check_refgen
from .statscov import check_stats

CHECKERS = (
    ("locks", check_locks),
    ("refgen", check_refgen),
    ("stats", check_stats),
    ("jit", check_jit),
    ("kernels", check_kernels),
    ("faultok", check_faultok),
)


def run_all(cfg: AnalysisConfig,
            only: set[str] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for name, fn in CHECKERS:
        if only and name not in only:
            continue
        findings.extend(fn(cfg))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def default_repo_root() -> Path:
    # src/repro/analysis/__init__.py -> repo root is three levels up
    return Path(__file__).resolve().parents[3]
