"""Refcount/generation-safety checker (the PR-7 race class).

KV blocks are pooled: ``free()`` recycles a block id immediately, so any
consumer still holding the id (an in-flight host-tier fetch, a draft
slot, a shared prefix) must either re-validate the block's generation
tag before writing through it or be redirected to the trash block.
PR 7's spill→free→realloc→fetch corruption was exactly a ``free`` call
whose consumer side lacked that check.

This checker enforces the pairing *structurally*: every call site of a
block-lifecycle API must sit in a function that shows evidence of the
consumer-side guard — a generation/liveness token in the same function
body — or carry an explicit ``# generation-safe: <why>`` annotation
(on the call line or the enclosing ``def``) recording the argument.

The evidence tokens are deliberately coarse (token presence in the
enclosing function's source): the goal is to force every free/demote
site to either colocate its guard or document the cross-function safety
argument where the reviewer of the *next* refactor will see it.
"""
from __future__ import annotations

import ast

from .config import AnalysisConfig
from .core import Finding, SourceModule, attr_chain, load_module

# lifecycle API -> tokens, any ONE of which counts as consumer-side
# evidence when present in the enclosing function's source
_RULES: dict[str, tuple[str, ...]] = {
    # freeing live ids: caller must flow through the retire/evict path
    # (which trash-redirects the slot tables) or check liveness itself
    "free": ("evicted_block_ids", "_retire_slot", "drain_preempted",
             "block_live", "generation", "_gen"),
    # dropping provisional (speculative) blocks: the slot's block_ids
    # must be trimmed in the same function so stale ids cannot be walked
    "release_provisional": ("del ", "block_ids[:", "generation"),
    # sharing a prefix block: only ids proven live may gain a ref
    "share": ("_lookup_prefix", "block_live", "generation"),
    # writing through a held id after any await/spill point
    "_write_block": ("block_live", "generation", "_gen"),
}


def _function_spans(tree: ast.Module):
    """Innermost-first (fn_node, start, end) spans for enclosing-function
    lookup; module-level code falls through to None."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            spans.append((node, node.lineno, node.end_lineno or node.lineno))
    spans.sort(key=lambda s: s[2] - s[1])  # innermost (smallest) first
    return spans


def _enclosing(spans, line: int):
    for node, start, end in spans:
        if start <= line <= end:
            return node, start, end
    return None, None, None


def check_refgen(cfg: AnalysisConfig) -> list[Finding]:
    findings: list[Finding] = []
    for rel in cfg.refgen_files:
        path = cfg.resolve(rel)
        if not path.exists():
            continue
        mod = load_module(path, cfg.repo_root)
        lines = mod.source.splitlines()
        spans = _function_spans(mod.tree)
        for sub in ast.walk(mod.tree):
            if not isinstance(sub, ast.Call):
                continue
            chain = attr_chain(sub.func)
            if not chain or len(chain) < 2:
                continue
            api = chain[-1]
            if api not in _RULES:
                continue
            fn, start, end = _enclosing(spans, sub.lineno)
            scope = fn.name if fn is not None else "<module>"
            # explicit annotation on the call line or the enclosing def
            if "generation-safe" in mod.annotations_at(sub.lineno):
                continue
            if fn is not None and \
                    mod.annotation(fn, "generation-safe") is not None:
                continue
            body = "\n".join(lines[start - 1:end]) if fn is not None else ""
            tokens = _RULES[api]
            if any(tok in body for tok in tokens):
                continue
            findings.append(Finding(
                checker="refgen", path=mod.rel, line=sub.lineno,
                rule=f"unproven-{api}", scope=f"{scope}@{api}",
                message=f"{'.'.join(chain)}() frees/recycles pool blocks "
                        f"but the enclosing function shows no "
                        f"generation/liveness guard (expected one of "
                        f"{tokens}) and no '# generation-safe:' "
                        f"annotation"))
    return findings
