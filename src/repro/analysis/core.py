"""Shared infrastructure for the repo-invariant lint pass.

The serving stack is a web of hand-maintained invariants — lock
discipline on scheduler/pool state, refcount+generation safety across
free/realloc cycles, the ``MERGE_RULES`` <-> ``_DERIVED`` stats
bijections, power-of-two shape keys into the jit compile caches.  The
checkers in this package prove those invariants *statically*, at lint
time, from nothing but the stdlib ``ast``/``tokenize`` modules — no
third-party dependencies, sub-second on this repo — so a new unguarded
field or unmerged stat fails the build instead of surfacing as a race
or a silently-dropped fleet counter three PRs later.

This module owns what every checker shares:

  * :class:`SourceModule` — one parsed file: AST, raw lines, and the
    per-line comment map (``tokenize``-extracted, so annotations in
    trailing comments are attributed to the statement's first line).
  * The **annotation convention** (:func:`parse_annotations`): trailing
    comments of the form ``# <key>: <value>`` with a small closed set of
    keys (``guarded-by``, ``assumes-lock``, ``alias-of``, ``owned-by``,
    ``generation-safe``, ``shape-static``, ``jit-ok``).  Annotations are
    the contract between the code and the checkers; an annotation is
    never a suppression of a *finding* (that is the baseline file's
    job) — it is a machine-checked statement about the code.
  * :class:`Finding` — one violation, with a line-independent stable id
    (``checker:path:scope:rule``) so the baseline survives unrelated
    edits to the file.
"""
from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

# The closed annotation vocabulary.  Anything else after a '#' is an
# ordinary comment; a typo'd key (e.g. "guarded_by") is itself reported
# by the lock checker so annotations cannot silently rot.
ANNOTATION_KEYS = (
    "guarded-by",       # field: every access must hold this lock
    "assumes-lock",     # function: caller guarantees this lock is held
    "alias-of",         # field: acquiring it acquires the named lock
    "owned-by",         # field: confined to the named thread
    "generation-safe",  # call site: free/realloc consumer safety argument
    "shape-static",     # call site: compile-cache key is bounded by design
    "jit-ok",           # statement: host-side code, never traced
    "fault-ok",         # except handler: why swallowing is correct
)


@dataclass
class Finding:
    checker: str                # "locks" | "refgen" | "stats" | "jit" | ...
    path: str                   # repo-relative posix path
    line: int
    rule: str                   # short machine id of the violated rule
    scope: str                  # Class.method / symbol the finding anchors to
    message: str
    suppressed: bool = False    # set by the baseline matcher

    @property
    def fid(self) -> str:
        """Stable identity: excludes the line number, so a baseline entry
        survives edits elsewhere in the file (the scope anchors it)."""
        return f"{self.checker}:{self.path}:{self.scope}:{self.rule}"

    def render(self) -> str:
        mark = " [baseline]" if self.suppressed else ""
        return (f"{self.path}:{self.line}: [{self.checker}/{self.rule}] "
                f"{self.scope}: {self.message}{mark}")

    def to_json(self) -> dict:
        return {"id": self.fid, "checker": self.checker, "path": self.path,
                "line": self.line, "rule": self.rule, "scope": self.scope,
                "message": self.message, "suppressed": self.suppressed}


@dataclass
class SourceModule:
    """One parsed source file plus its comment map."""
    path: Path                  # absolute
    rel: str                    # repo-relative posix path (finding anchor)
    source: str
    tree: ast.Module
    comments: dict[int, str] = field(default_factory=dict)  # line -> text

    def annotations_at(self, line: int) -> dict[str, str]:
        return parse_annotations(self.comments.get(line, ""))

    def annotation(self, node: ast.AST, key: str) -> str | None:
        """Annotation attached to ``node``: on its first line, or (for
        defs) on the line directly above the ``def`` — decorators and
        long signatures make same-line comments awkward there."""
        ann = self.annotations_at(node.lineno).get(key)
        if ann is None and isinstance(node, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
            ann = self.annotations_at(node.lineno - 1).get(key)
        return ann


def parse_annotations(comment: str) -> dict[str, str]:
    """``# guarded-by: self._lock`` -> {"guarded-by": "self._lock"}.
    Several annotations may share a line, ';'-separated."""
    out: dict[str, str] = {}
    if not comment:
        return out
    for part in comment.lstrip("#").split(";"):
        if ":" not in part:
            continue
        key, _, value = part.partition(":")
        key = key.strip()
        if key in ANNOTATION_KEYS:
            out[key] = value.strip()
    return out


def load_module(path: Path, repo_root: Path) -> SourceModule:
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    comments: dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                # last comment on a line wins (there is only ever one)
                comments[tok.start[0]] = tok.string
    except tokenize.TokenError:
        pass                      # a parsed file that fails tokenize is fine
    rel = path.resolve().relative_to(repo_root.resolve()).as_posix()
    return SourceModule(path=path, rel=rel, source=source, tree=tree,
                        comments=comments)


def attr_chain(node: ast.AST) -> list[str] | None:
    """``self.pool.free`` -> ["self", "pool", "free"]; None when the
    expression is not a plain name/attribute chain (calls, subscripts)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def iter_functions(cls: ast.ClassDef):
    """(name, def-node) for every method of ``cls`` (direct children)."""
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield item.name, item


def dict_literal_keys(node: ast.AST) -> list[str]:
    """String keys of a dict literal AST node (non-string keys skipped)."""
    keys: list[str] = []
    if isinstance(node, ast.Dict):
        for k in node.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.append(k.value)
    return keys
