"""Repo-specific configuration for the invariant lint pass.

The checkers themselves are generic AST machinery; everything this repo
knows about itself — which files carry lock discipline, how attribute
names resolve to classes across modules, which call edges exist only
dynamically (hooks), which jit entry points key compile caches — lives
here, in one reviewable table, so tightening the lint is a config edit
and the analyzer's own tests can run the same checkers against fixture
trees with a fixture config.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path


@dataclass
class AnalysisConfig:
    repo_root: Path

    # -- lock discipline -------------------------------------------------------
    # files whose classes carry guarded-by/assumes-lock annotations and
    # whose with-blocks feed the lock-acquisition-order graph
    lock_files: list[str] = field(default_factory=list)
    # (ClassName, attr) -> ClassName: how `self.<attr>.<field>` accesses
    # and `self.<attr>.<method>()` calls resolve across classes
    attr_types: dict[tuple[str, str], str] = field(default_factory=dict)
    # call edges the AST cannot see (callbacks installed at runtime):
    # (Class, method) -> list of (Class, method) it may invoke
    extra_call_edges: dict[tuple[str, str], list[tuple[str, str]]] = \
        field(default_factory=dict)
    # Class -> methods that run on a *different* thread than the one its
    # owned-by-annotated fields are confined to; touching an owned field
    # from one of these is a confinement violation
    entry_points: dict[str, set[str]] = field(default_factory=dict)
    # files where every threading.Thread(...) must pass name= and daemon=
    thread_files: list[str] = field(default_factory=list)

    # -- refcount/generation safety --------------------------------------------
    refgen_files: list[str] = field(default_factory=list)

    # -- fault routing ---------------------------------------------------------
    # files where a broad except handler may not silently swallow
    # (see .faultok): the serving/offload fault paths
    fault_files: list[str] = field(default_factory=list)

    # -- stats coverage --------------------------------------------------------
    stats_file: str = ""            # defines ServeStats/MERGE_RULES/_DERIVED
    stats_mutation_files: list[str] = field(default_factory=list)

    # -- jit purity ------------------------------------------------------------
    jit_files: list[str] = field(default_factory=list)
    shape_cache_file: str = ""      # file whose compile-cache keys are checked
    shape_cache_attr: str = "_prefill_shapes"

    # -- kernel registry -------------------------------------------------------
    kernels_dir: str = ""           # src/repro/kernels
    kernel_bench: str = ""          # benchmarks/kernel_bench.py

    def resolve(self, rel: str) -> Path:
        return self.repo_root / rel


def repo_config(repo_root: Path) -> AnalysisConfig:
    """The configuration for *this* repository."""
    serving = "src/repro/serving"
    return AnalysisConfig(
        repo_root=repo_root,
        lock_files=[
            f"{serving}/scheduler.py",
            f"{serving}/kv_pool.py",
            f"{serving}/engine.py",
            f"{serving}/router.py",
            "src/repro/core/offload.py",
        ],
        attr_types={
            ("ContinuousScheduler", "pool"): "KVBlockPool",
            ("ServingEngine", "pool"): "KVBlockPool",
            ("ServingEngine", "scheduler"): "ContinuousScheduler",
            ("ServingEngine", "_kv_io"): "OffloadEngine",
            ("ServingEngine", "_drafter"): "_Drafter",
            ("_Drafter", "pool"): "KVBlockPool",
            ("KVBlockPool", "host"): "HostTier",
            ("ReplicaTarget", "engine"): "ServingEngine",
            ("KVBlockTarget", "tier"): "HostTier",
            ("_MigrationAdapter", "engine"): "ServingEngine",
            ("_MigrationAdapter", "router"): "ReplicaRouter",
        },
        extra_call_edges={
            # pool.on_demote is installed by the tiered engine at
            # construction; _demote_locked invokes it under the pool lock
            ("KVBlockPool", "_demote_locked"):
                [("ServingEngine", "_on_demote")],
            # disaggregated migration: the router installs _on_prefilled
            # on prefill-role engines, so prefill completion calls back
            # into the router, which submits a migrate payload whose
            # KVBlockTarget "tier" is a _MigrationAdapter that lands the
            # blocks via adopt_blocks on the chosen decode replica
            ("ServingEngine", "_handoff"):
                [("ReplicaRouter", "_migrate")],
            ("KVBlockTarget", "execute"):
                [("_MigrationAdapter", "adopt")],
            ("_MigrationAdapter", "adopt"):
                [("ServingEngine", "adopt_blocks")],
        },
        entry_points={
            # ServingEngine state is confined to the executor thread;
            # these methods run on router / traffic / control threads
            "ServingEngine": {"submit", "_check_fits", "load_snapshot",
                              "load", "start", "stop", "failure",
                              "_raise_failure_once", "_spill_done",
                              "_kv_fault_hook", "adopt_blocks"},
            # the rebalance loop runs on the steal thread, failure
            # routing runs on whichever replica thread terminated the
            # request, and the migration path runs on source executor
            # threads (_migrate) and the migration worker (_mig_done,
            # _place_migration); dispatch-thread state (the fleet
            # prefix index) must stay off all of them
            "ReplicaRouter": {"_rebalance_once", "_steal_loop",
                              "_heartbeat", "_on_request_failed",
                              "_migrate", "_select_decode", "_mig_done",
                              "_place_migration", "drain_migrations"},
        },
        thread_files=[
            f"{serving}/engine.py",
            f"{serving}/router.py",
            "src/repro/core/offload.py",
        ],
        refgen_files=[
            f"{serving}/scheduler.py",
            f"{serving}/engine.py",
            f"{serving}/router.py",
        ],
        fault_files=[
            f"{serving}/scheduler.py",
            f"{serving}/kv_pool.py",
            f"{serving}/engine.py",
            f"{serving}/router.py",
            f"{serving}/faults.py",
            "src/repro/core/offload.py",
        ],
        stats_file=f"{serving}/engine.py",
        stats_mutation_files=[
            f"{serving}/engine.py",
            f"{serving}/router.py",
        ],
        jit_files=[
            "src/repro/models",
            "src/repro/kernels",
            "src/repro/common.py",
            f"{serving}/engine.py",
        ],
        shape_cache_file=f"{serving}/engine.py",
        kernels_dir="src/repro/kernels",
        kernel_bench="benchmarks/kernel_bench.py",
    )
