"""Stats-coverage checker.

``ServeStats`` is merged across replicas declaratively: ``MERGE_RULES``
maps each field to its fleet-merge combinator and ``_DERIVED`` recomputes
ratio fields from merged numerators/denominators.  PR 3 shipped fleet
stats that were never populated and PR 6 hand-patched derived ratios —
both were runtime-test catches of what is really a static property:

  fields(ServeStats) == keys(MERGE_RULES) ∪ keys(_DERIVED), disjointly.

This checker lifts that bijection to lint time, and additionally proves
every stats counter *mutated* in the engine/router (``<stats>.f += ...``)
is a declared field of its dataclass — a typo'd counter name otherwise
accumulates into ``__dict__`` and silently never merges.
"""
from __future__ import annotations

import ast

from .config import AnalysisConfig
from .core import Finding, attr_chain, load_module

# receivers whose attribute mutations are stats-counter mutations, and
# the dataclass whose fields they must belong to
_STATS_RECEIVERS = {
    "stats": ("ServeStats", "RouterStats"),
    "totals": ("ServeStats",),
    "rbase": ("RouterStats",),
}


def _dataclass_fields(tree: ast.Module, name: str) -> set[str]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return {item.target.id for item in node.body
                    if isinstance(item, ast.AnnAssign)
                    and isinstance(item.target, ast.Name)}
    return set()


def _module_dict(tree: ast.Module, name: str) -> tuple[dict[str, str], int]:
    """String-keyed dict literal assigned to module global ``name``;
    values kept when they are string constants (merge-rule names), else
    ``""`` (e.g. the _DERIVED lambdas)."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        else:
            continue
        for tgt in targets:
            if isinstance(tgt, ast.Name) and tgt.id == name \
                    and isinstance(node.value, ast.Dict):
                out: dict[str, str] = {}
                for k, v in zip(node.value.keys, node.value.values):
                    if isinstance(k, ast.Constant) and \
                            isinstance(k.value, str):
                        out[k.value] = v.value \
                            if isinstance(v, ast.Constant) and \
                            isinstance(v.value, str) else ""
                return out, node.lineno
    return {}, 0


def check_stats(cfg: AnalysisConfig) -> list[Finding]:
    findings: list[Finding] = []
    if not cfg.stats_file:
        return findings
    stats_path = cfg.resolve(cfg.stats_file)
    if not stats_path.exists():
        return findings
    mod = load_module(stats_path, cfg.repo_root)

    fields = _dataclass_fields(mod.tree, "ServeStats")
    merge_rules, merge_line = _module_dict(mod.tree, "MERGE_RULES")
    derived_keys, derived_line = _module_dict(mod.tree, "_DERIVED")
    merge = set(merge_rules)
    derived = set(derived_keys)
    declared_derived = {k for k, v in merge_rules.items() if v == "derived"}

    for f in sorted(fields - merge):
        findings.append(Finding(
            checker="stats", path=mod.rel, line=merge_line,
            rule="unmerged-field", scope=f,
            message=f"ServeStats.{f} has no MERGE_RULES entry — it will "
                    f"silently reset on fleet merge"))
    for f in sorted(merge - fields):
        findings.append(Finding(
            checker="stats", path=mod.rel, line=merge_line,
            rule="stale-rule", scope=f,
            message=f"MERGE_RULES entry '{f}' names no ServeStats field"))
    # bijection between rules declared "derived" and _DERIVED recomputes
    for f in sorted(declared_derived - derived):
        findings.append(Finding(
            checker="stats", path=mod.rel, line=derived_line,
            rule="derived-mismatch", scope=f,
            message=f"'{f}' is declared 'derived' in MERGE_RULES but has "
                    f"no _DERIVED recompute — it keeps a stale ratio "
                    f"after merge"))
    for f in sorted(derived - declared_derived):
        findings.append(Finding(
            checker="stats", path=mod.rel, line=derived_line,
            rule="derived-mismatch", scope=f,
            message=f"_DERIVED recomputes '{f}' but MERGE_RULES does not "
                    f"declare it 'derived' — the fold result is "
                    f"overwritten"))

    # counter mutations: <...>.stats.f += / <...>.totals.f += must name a
    # declared field of the corresponding stats dataclass
    known: dict[str, set[str]] = {"ServeStats": fields}
    for rel in cfg.stats_mutation_files:
        path = cfg.resolve(rel)
        if not path.exists():
            continue
        m = load_module(path, cfg.repo_root)
        for cls in ("ServeStats", "RouterStats"):
            if cls not in known:
                got = _dataclass_fields(m.tree, cls)
                if got:
                    known[cls] = got
        for sub in ast.walk(m.tree):
            if not isinstance(sub, (ast.AugAssign, ast.Assign)):
                continue
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            for tgt in targets:
                chain = attr_chain(tgt)
                if not chain or len(chain) < 2:
                    continue
                recv, fname = chain[-2], chain[-1]
                classes = _STATS_RECEIVERS.get(recv)
                if classes is None:
                    continue
                ok = any(fname in known.get(c, set()) for c in classes)
                if not ok and any(c in known for c in classes):
                    findings.append(Finding(
                        checker="stats", path=m.rel, line=sub.lineno,
                        rule="unknown-counter",
                        scope=f"{recv}.{fname}",
                        message=f"mutation of {recv}.{fname} names no "
                                f"declared field of "
                                f"{'/'.join(classes)} — it will never "
                                f"merge"))
    return findings
