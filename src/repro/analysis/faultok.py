"""Silent-swallow checker (the PR-9 fault-tolerance discipline).

The fault-tolerance layer's whole contract is that failures are *routed*
— to a request's FAILED terminal, to the router's retry path, to the
crash capture that stop() re-raises — never dropped on the floor.  A
``except Exception: pass`` (or a log-and-drop) in the serving/offload
stack silently converts a routed failure into a hang or a leak, which is
exactly the bug class PR 9 exists to kill.

This checker flags every *broad* exception handler (bare ``except``,
``except Exception``, ``except BaseException``, or a tuple containing
one of those) in the configured files whose body does nothing but
swallow — only ``pass`` / ``continue`` / ``break`` statements and
log-like calls (``print``, ``logging`` methods) — unless the handler
carries an explicit ``# fault-ok: <reason>`` annotation on the
``except`` line (or the line above) recording why dropping is correct
there.  Handlers that re-raise, transform, or route the exception into
real code are not flagged: the rule targets silence, not breadth.
"""
from __future__ import annotations

import ast

from .config import AnalysisConfig
from .core import Finding, attr_chain, load_module

_BROAD = ("Exception", "BaseException")
# call names whose invocation still counts as "dropping" the failure:
# telling a human is not routing it through the recovery machinery
_LOG_CALLS = ("print", "log", "debug", "info", "warning", "warn",
              "error", "exception")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:                      # bare except
        return True
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for sub in types:
        chain = attr_chain(sub)
        if chain and chain[-1] in _BROAD:
            return True
    return False


def _is_log_call(node: ast.AST) -> bool:
    if not (isinstance(node, ast.Expr) and isinstance(node.value, ast.Call)):
        return False
    chain = attr_chain(node.value.func)
    return bool(chain) and chain[-1] in _LOG_CALLS


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body only drops: pass/continue/break,
    docstrings, and log-like calls.  Any other statement is treated as
    routing the failure somewhere real."""
    for stmt in handler.body:
        if isinstance(stmt, (ast.Pass, ast.Continue, ast.Break)):
            continue
        if isinstance(stmt, ast.Expr) and \
                isinstance(stmt.value, ast.Constant):
            continue                   # stray docstring/ellipsis
        if _is_log_call(stmt):
            continue
        return False
    return True


def _enclosing_name(tree: ast.Module, line: int) -> str:
    best, size = "<module>", None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            end = node.end_lineno or node.lineno
            if node.lineno <= line <= end and \
                    (size is None or end - node.lineno < size):
                best, size = node.name, end - node.lineno
    return best


def check_faultok(cfg: AnalysisConfig) -> list[Finding]:
    findings: list[Finding] = []
    for rel in cfg.fault_files:
        path = cfg.resolve(rel)
        if not path.exists():
            continue
        mod = load_module(path, cfg.repo_root)
        for handler in ast.walk(mod.tree):
            if not isinstance(handler, ast.ExceptHandler):
                continue
            if not _is_broad(handler) or not _swallows(handler):
                continue
            if "fault-ok" in mod.annotations_at(handler.lineno) or \
                    "fault-ok" in mod.annotations_at(handler.lineno - 1):
                continue
            scope = _enclosing_name(mod.tree, handler.lineno)
            findings.append(Finding(
                checker="faultok", path=mod.rel, line=handler.lineno,
                rule="silent-swallow", scope=f"{scope}@{handler.lineno}",
                message="broad exception handler silently drops the "
                        "failure (body is only pass/continue/log); route "
                        "it through the fault path or annotate the line "
                        "with '# fault-ok: <reason>'"))
    return findings
