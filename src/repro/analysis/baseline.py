"""Baseline suppression file.

Triaged findings live in ``analysis_baseline.json`` at the repo root:

    {"findings": {"<finding-id>": "<triage note>", ...}}

Finding ids are line-independent (``checker:path:scope:rule``), so a
baseline entry survives unrelated edits to the file and dies exactly
when the flagged scope is fixed or removed — at which point the entry
is *stale* and reported, keeping the baseline shrink-only.  A baseline
entry is a debt marker with an owner note, not an annotation: code
that is *correct* gets a machine-checked annotation (``generation-safe``,
``jit-ok``); code that is *wrong but triaged* gets a baseline entry.
"""
from __future__ import annotations

import json
from pathlib import Path

from .core import Finding

BASELINE_NAME = "analysis_baseline.json"


def load_baseline(repo_root: Path) -> dict[str, str]:
    path = repo_root / BASELINE_NAME
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return dict(data.get("findings", {}))


def apply_baseline(findings: list[Finding],
                   baseline: dict[str, str]) -> list[str]:
    """Mark suppressed findings in place; return stale baseline ids
    (entries that matched nothing — fixed code whose debt marker must
    now be deleted)."""
    live = set()
    for f in findings:
        if f.fid in baseline:
            f.suppressed = True
            live.add(f.fid)
    return sorted(set(baseline) - live)


def write_baseline(repo_root: Path, findings: list[Finding],
                   note: str) -> Path:
    """Rewrite the baseline to suppress ``findings``, stamping ``note``
    as the triage justification on every *new* entry.  Entries that were
    already in the baseline keep their original note — the justification
    belongs to the triage that first admitted the debt, not to whoever
    re-ran the tool later.  An empty note is refused: a debt marker
    without an owner note is exactly the TODO-stamp anti-pattern this
    replaces."""
    note = note.strip()
    if not note:
        raise ValueError("baseline entries need a triage note "
                         "(--note 'why this finding is acceptable debt')")
    path = repo_root / BASELINE_NAME
    old = load_baseline(repo_root)
    entries = {f.fid: old.get(f.fid, f"triaged: {note}") for f in findings}
    path.write_text(json.dumps({"findings": entries}, indent=2,
                               sort_keys=True) + "\n")
    return path
