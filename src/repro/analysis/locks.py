"""Lock-discipline checker.

Three related proofs over the serving stack's concurrency annotations:

1. **Guarded access** — every read/write of a field annotated
   ``# guarded-by: self._lock`` happens inside a ``with`` block that
   holds that lock (alias-aware: acquiring a ``Condition`` annotated
   ``# alias-of: self._lock`` counts) or inside a method annotated
   ``# assumes-lock: <lock>``.  Fields annotated
   ``# owned-by: <thread>`` are thread-confined instead of
   lock-guarded: they may be touched anywhere *except* the configured
   cross-thread entry points of their class.

2. **Lock-acquisition order** — a static graph with one node per
   canonical lock (``Class._lock``) and an edge A→B wherever B is
   acquired while A is held, including *transitively* through calls
   (``self.m()``, typed attribute chains via the config attr map, and
   config-injected dynamic edges for runtime-installed hooks like
   ``pool.on_demote``).  Any cycle is a potential deadlock and fails
   the build.

3. **Thread hygiene** — every ``threading.Thread(...)`` constructed in
   the configured serving/core modules must pass explicit ``name=`` and
   ``daemon=`` (the repo policy: named daemon workers, joined in
   ``stop()``; the policy itself is asserted at runtime by
   ``tests/test_threads.py``).

``__init__`` bodies are exempt from guarded-access checking: the object
is not yet published to other threads while it is being constructed.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field as dc_field

from .config import AnalysisConfig
from .core import (ANNOTATION_KEYS, Finding, SourceModule, attr_chain,
                   iter_functions, load_module)

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
# annotation-shaped comment keys that are NOT in the vocabulary (typo
# rot): checked only in annotation *position* — the text before the
# first ':' of a ';'-separated segment, mirroring parse_annotations —
# so prose mentioning "shape-keyed: ..." mid-sentence is not flagged
_ANN_ROT = re.compile(
    r"(?:guarded|assumes|alias|owned|generation|shape|jit)"
    r"[-_][a-z][a-z_-]*")


@dataclass
class _Cls:
    name: str
    mod: SourceModule
    node: ast.ClassDef
    guarded: dict[str, str] = dc_field(default_factory=dict)  # field -> lock
    owned: dict[str, str] = dc_field(default_factory=dict)    # field -> thread
    aliases: dict[str, str] = dc_field(default_factory=dict)  # field -> lock
    locks: set[str] = dc_field(default_factory=set)           # lock fields


class _Ctx:
    """Shared state across all method walks: findings, the lock-order
    edge set, per-method direct acquisitions, and call sites."""

    def __init__(self, cfg: AnalysisConfig, classes: dict[str, _Cls]):
        self.cfg = cfg
        self.classes = classes
        self.findings: list[Finding] = []
        # (lock_a, lock_b) -> (rel, line) of the first site creating it
        self.edges: dict[tuple[str, str], tuple[str, int]] = {}
        self.direct: dict[tuple[str, str], set[str]] = {}
        self.assumed: dict[tuple[str, str], set[str]] = {}
        self.calls: dict[tuple[str, str], set[tuple[str, str]]] = {}
        # (caller, callee, held-frozenset, rel, line)
        self.call_sites: list[tuple] = []

    def edge(self, a: str, b: str, rel: str, line: int) -> None:
        if a != b:  # same-lock re-entry is RLock reentrancy, not an order
            self.edges.setdefault((a, b), (rel, line))

    def acquire(self, key: tuple[str, str], lock: str) -> None:
        self.direct.setdefault(key, set()).add(lock)


def _canon_value(cls_name: str, text: str) -> str:
    """Annotation value -> canonical lock name.  ``self._lock`` in class
    C becomes ``C._lock``; anything else is taken as already canonical
    (``KVBlockPool._lock`` for cross-class assumptions)."""
    text = text.strip()
    if text.startswith("self."):
        return f"{cls_name}.{text[5:]}"
    return text


def _collect_class(mod: SourceModule, node: ast.ClassDef) -> _Cls:
    cls = _Cls(node.name, mod, node)
    for sub in ast.walk(node):
        if not isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            continue
        targets = sub.targets if isinstance(sub, ast.Assign) else [sub.target]
        for tgt in targets:
            chain = attr_chain(tgt)
            if not chain or chain[0] != "self" or len(chain) != 2:
                continue
            f = chain[1]
            # a multi-line assignment may carry its trailing annotation
            # on any of its physical lines
            ann: dict[str, str] = {}
            for line in range(sub.lineno, (sub.end_lineno or sub.lineno) + 1):
                ann.update(mod.annotations_at(line))
            if "guarded-by" in ann:
                cls.guarded[f] = _canon_value(cls.name, ann["guarded-by"])
            if "owned-by" in ann:
                cls.owned[f] = ann["owned-by"]
            if "alias-of" in ann:
                cls.aliases[f] = _canon_value(cls.name, ann["alias-of"])
            value = getattr(sub, "value", None)
            if isinstance(value, ast.Call):
                fchain = attr_chain(value.func)
                if fchain and fchain[-1] in _LOCK_CTORS:
                    cls.locks.add(f)
    return cls


class _Walker:
    """Walks one method body tracking the set of held canonical locks."""

    def __init__(self, ctx: _Ctx, cls: _Cls, meth: str, is_entry: bool,
                 check_access: bool = True):
        self.ctx = ctx
        self.cls = cls
        self.meth = meth
        self.key = (cls.name, meth)
        self.is_entry = is_entry
        self.check_access = check_access
        self.scope = f"{cls.name}.{meth}"
        self._reported: set[tuple[int, str]] = set()

    # -- lock expression resolution -------------------------------------------

    def resolve_lock(self, expr: ast.AST) -> set[str]:
        chain = attr_chain(expr)
        if not chain or chain[0] != "self":
            return set()
        if len(chain) == 2:
            f = chain[1]
            if f in self.cls.aliases:
                return {self.cls.aliases[f]}
            return {f"{self.cls.name}.{f}"}
        if len(chain) == 3:
            tname = self.ctx.cfg.attr_types.get((self.cls.name, chain[1]))
            target = self.ctx.classes.get(tname) if tname else None
            if target is not None:
                f = chain[2]
                if f in target.aliases:
                    return {target.aliases[f]}
                return {f"{target.name}.{f}"}
        return set()

    # -- statement walk --------------------------------------------------------

    def walk(self, stmts: list[ast.stmt], held: frozenset[str]) -> None:
        for s in stmts:
            self._stmt(s, held)

    def _stmt(self, s: ast.stmt, held: frozenset[str]) -> None:
        if isinstance(s, ast.With):
            acquired: set[str] = set()
            for item in s.items:
                self._scan(item.context_expr, held, lock_expr=True)
                for lock in self.resolve_lock(item.context_expr):
                    self.ctx.acquire(self.key, lock)
                    for h in held:
                        self.ctx.edge(h, lock, self.cls.mod.rel, s.lineno)
                    acquired.add(lock)
                if item.optional_vars is not None:
                    self._scan(item.optional_vars, held)
            self.walk(s.body, held | frozenset(acquired))
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: runs later, usually on another thread (worker
            # loops) — fresh scope, empty lock set, never entry-restricted
            inner = _Walker(self.ctx, self.cls, f"{self.meth}.{s.name}",
                            is_entry=False, check_access=self.check_access)
            assumed = self.cls.mod.annotation(s, "assumes-lock")
            held0 = frozenset(_canon_value(self.cls.name, a)
                              for a in assumed.split(",")) if assumed \
                else frozenset()
            inner.walk(s.body, held0)
        elif isinstance(s, (ast.If, ast.While)):
            self._scan(s.test, held)
            self.walk(s.body, held)
            self.walk(s.orelse, held)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._scan(s.target, held)
            self._scan(s.iter, held)
            self.walk(s.body, held)
            self.walk(s.orelse, held)
        elif isinstance(s, ast.Try):
            self.walk(s.body, held)
            for h in s.handlers:
                self.walk(h.body, held)
            self.walk(s.orelse, held)
            self.walk(s.finalbody, held)
        elif isinstance(s, ast.ClassDef):
            pass
        else:
            self._scan(s, held)

    # -- expression scan -------------------------------------------------------

    def _scan(self, node: ast.AST, held: frozenset[str],
              lock_expr: bool = False) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute):
                self._access(sub, held, lock_expr)
            elif isinstance(sub, ast.Call):
                self._call(sub, held)

    def _report(self, line: int, field: str, rule: str, msg: str) -> None:
        if (line, field) in self._reported:
            return
        self._reported.add((line, field))
        self.ctx.findings.append(Finding(
            checker="locks", path=self.cls.mod.rel, line=line, rule=rule,
            scope=self.scope, message=msg))

    def _access(self, sub: ast.Attribute, held: frozenset[str],
                lock_expr: bool) -> None:
        if not self.check_access:
            return
        chain = attr_chain(sub)
        if not chain or chain[0] != "self" or len(chain) < 2:
            return
        f = chain[1]
        if f in self.cls.guarded:
            lock = self.cls.guarded[f]
            if lock not in held:
                self._report(
                    sub.lineno, f, "unguarded-field",
                    f"access to self.{f} (guarded-by {lock}) without "
                    f"holding the lock")
        elif f in self.cls.owned and self.is_entry:
            self._report(
                sub.lineno, f, "owned-cross-thread",
                f"self.{f} is owned-by {self.cls.owned[f]} but "
                f"{self.meth}() runs on another thread")
        elif len(chain) >= 3:
            tname = self.ctx.cfg.attr_types.get((self.cls.name, f))
            target = self.ctx.classes.get(tname) if tname else None
            if target is None:
                return
            g = chain[2]
            if g in target.guarded and not (lock_expr and len(chain) == 3):
                lock = target.guarded[g]
                if lock not in held:
                    self._report(
                        sub.lineno, f"{f}.{g}", "unguarded-field",
                        f"access to self.{f}.{g} (guarded-by {lock}) "
                        f"without holding the lock")

    def _call(self, sub: ast.Call, held: frozenset[str]) -> None:
        chain = attr_chain(sub.func)
        if not chain or chain[0] != "self":
            return
        if len(chain) == 2:
            callee = (self.cls.name, chain[1])
        elif len(chain) == 3:
            tname = self.ctx.cfg.attr_types.get((self.cls.name, chain[1]))
            if tname is None:
                return
            callee = (tname, chain[2])
        else:
            return
        self.ctx.calls.setdefault(self.key, set()).add(callee)
        self.ctx.call_sites.append(
            (self.key, callee, held, self.cls.mod.rel, sub.lineno))


def _transitive_acquired(ctx: _Ctx) -> dict[tuple[str, str], set[str]]:
    """Fixpoint: locks each (Class, method) may acquire, directly or via
    any call it makes (including config-injected dynamic edges)."""
    calls = {k: set(v) for k, v in ctx.calls.items()}
    for src, dsts in ctx.cfg.extra_call_edges.items():
        calls.setdefault(src, set()).update(dsts)
    star = {k: set(v) for k, v in ctx.direct.items()}
    changed = True
    while changed:
        changed = False
        for key, callees in calls.items():
            cur = star.setdefault(key, set())
            for c in callees:
                extra = star.get(c)
                if extra and not extra <= cur:
                    cur |= extra
                    changed = True
    return star


def _find_cycles(edges: dict[tuple[str, str], tuple[str, int]]):
    adj: dict[str, set[str]] = {}
    for a, b in edges:
        adj.setdefault(a, set()).add(b)
        adj.setdefault(b, set())
    color: dict[str, int] = {}
    stack: list[str] = []
    cycles: list[list[str]] = []

    def dfs(n: str) -> None:
        color[n] = 1
        stack.append(n)
        for m in sorted(adj[n]):
            if color.get(m, 0) == 0:
                dfs(m)
            elif color[m] == 1:
                cycles.append(stack[stack.index(m):] + [m])
        stack.pop()
        color[n] = 2

    for n in sorted(adj):
        if color.get(n, 0) == 0:
            dfs(n)
    return cycles


def _check_threads(mod: SourceModule, findings: list[Finding]) -> None:
    for sub in ast.walk(mod.tree):
        if not isinstance(sub, ast.Call):
            continue
        chain = attr_chain(sub.func)
        if not chain or chain[-1] != "Thread":
            continue
        if len(chain) > 1 and chain[-2] != "threading":
            continue
        kw = {k.arg for k in sub.keywords}
        missing = [k for k in ("name", "daemon") if k not in kw]
        if missing:
            findings.append(Finding(
                checker="locks", path=mod.rel, line=sub.lineno,
                rule="thread-hygiene", scope=f"Thread@{sub.lineno}",
                message=f"threading.Thread(...) without explicit "
                        f"{'/'.join(missing)}= (policy: named daemon "
                        f"workers, joined in stop())"))


def _check_annotation_rot(mod: SourceModule, findings: list[Finding]) -> None:
    for line, comment in mod.comments.items():
        for part in comment.lstrip("#").split(";"):
            key = part.partition(":")[0].strip()
            if _ANN_ROT.fullmatch(key) and key not in ANNOTATION_KEYS:
                findings.append(Finding(
                    checker="locks", path=mod.rel, line=line,
                    rule="bad-annotation", scope=key,
                    message=f"comment key '{key}' is not in the "
                            f"annotation vocabulary {ANNOTATION_KEYS}"))


def check_locks(cfg: AnalysisConfig) -> list[Finding]:
    mods: list[SourceModule] = []
    for rel in cfg.lock_files:
        path = cfg.resolve(rel)
        if path.exists():
            mods.append(load_module(path, cfg.repo_root))

    classes: dict[str, _Cls] = {}
    for mod in mods:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _collect_class(mod, node)

    ctx = _Ctx(cfg, classes)

    for cls in classes.values():
        entries = cfg.entry_points.get(cls.name, set())
        for name, fn in iter_functions(cls.node):
            assumed = cls.mod.annotation(fn, "assumes-lock")
            held0 = frozenset(_canon_value(cls.name, a)
                              for a in assumed.split(",")) if assumed \
                else frozenset()
            walker = _Walker(ctx, cls, name, is_entry=name in entries,
                             check_access=name != "__init__")
            if held0:
                ctx.assumed[(cls.name, name)] = set(held0)
            walker.walk(fn.body, held0)

    # dynamic hook edges (config): the hook fires somewhere inside the
    # source method — conservatively, while it holds everything it ever
    # directly acquires or assumes
    for src, dsts in cfg.extra_call_edges.items():
        held = frozenset(ctx.direct.get(src, set()) |
                         ctx.assumed.get(src, set()))
        src_cls = classes.get(src[0])
        rel = src_cls.mod.rel if src_cls else ""
        for dst in dsts:
            ctx.call_sites.append((src, dst, held, rel, 0))

    # call-site transitive edges: calling a method that (transitively)
    # acquires lock L while holding H adds H -> L
    star = _transitive_acquired(ctx)
    for caller, callee, held, rel, line in ctx.call_sites:
        for lock in star.get(callee, ()):
            for h in held:
                ctx.edge(h, lock, rel, line)

    for cycle in _find_cycles(ctx.edges):
        first = ctx.edges.get((cycle[0], cycle[1]),
                              (mods[0].rel if mods else "", 0))
        ctx.findings.append(Finding(
            checker="locks", path=first[0], line=first[1],
            rule="lock-order-cycle", scope=" -> ".join(cycle),
            message=f"lock acquisition cycle {' -> '.join(cycle)} "
                    f"(potential deadlock)"))

    thread_mods = {m.rel: m for m in mods}
    for rel in cfg.thread_files:
        path = cfg.resolve(rel)
        if not path.exists():
            continue
        mod = thread_mods.get(rel) or load_module(path, cfg.repo_root)
        _check_threads(mod, ctx.findings)

    for mod in mods:
        _check_annotation_rot(mod, ctx.findings)

    return ctx.findings
