"""Jit-purity checker.

Three trace-safety hazards, scoped to the model/kernel/serving hot path:

1. **Tracer branches** — ``if``/``while`` whose test calls a
   value-producing jnp reduction (``jnp.any``, ``jnp.isnan``, ...).
   Under ``jax.jit`` that forces a trace-time concretization error; in
   op-by-op mode it silently syncs device→host per step.  Host-side
   helpers that are *meant* to pull values (test-only NaN probes) carry
   ``# jit-ok: <why>`` on the branch line or the enclosing ``def``.

2. **Tracer scalarization** — ``.item()`` / ``float(jnp.*(...))`` in
   the same files, same annotation escape.

3. **Compile-cache shape keys** — every call feeding the prefill/verify
   shape caches (``self._prefill_shapes.add(...)``) must sit in a
   function with power-of-two bucketing evidence (``_bucket_len`` or a
   doubling loop) or be annotated ``# shape-static: <why>``; an
   unbucketed shape key means one XLA compile per distinct request
   length — the compile-storm failure mode.
"""
from __future__ import annotations

import ast

from .config import AnalysisConfig
from .core import Finding, SourceModule, attr_chain, load_module

# jnp functions whose *value* depends on array contents — branching on
# them is data-dependent control flow.  jnp.issubdtype/shape/ndim etc.
# are static and deliberately absent.
_VALUE_FUNCS = {
    "any", "all", "sum", "max", "min", "mean", "prod",
    "isnan", "isfinite", "isinf", "argmax", "argmin",
    "allclose", "array_equal", "count_nonzero",
}
_ARRAY_MODULES = {"jnp", "np_like", "jax"}

_POW2_EVIDENCE = ("_bucket_len", "*= 2", "* 2")


def _jit_paths(cfg: AnalysisConfig):
    for rel in cfg.jit_files:
        path = cfg.resolve(rel)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.exists():
            yield path


def _value_call(node: ast.AST) -> str | None:
    """'jnp.any' if the expression tree contains a call to a
    value-producing array reduction, else None."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = attr_chain(sub.func)
            if chain and len(chain) >= 2 and chain[0] in _ARRAY_MODULES \
                    and chain[-1] in _VALUE_FUNCS:
                return ".".join(chain)
    return None


def _enclosing_defs(tree: ast.Module):
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            spans.append((node, node.lineno, node.end_lineno or node.lineno))
    spans.sort(key=lambda s: s[2] - s[1])
    return spans


def _annotated(mod: SourceModule, spans, line: int, key: str) -> bool:
    if key in mod.annotations_at(line):
        return True
    for node, start, end in spans:
        if start <= line <= end:
            return mod.annotation(node, key) is not None
    return False


def check_jit(cfg: AnalysisConfig) -> list[Finding]:
    findings: list[Finding] = []
    for path in _jit_paths(cfg):
        mod = load_module(path, cfg.repo_root)
        spans = _enclosing_defs(mod.tree)
        lines = mod.source.splitlines()

        for sub in ast.walk(mod.tree):
            if isinstance(sub, (ast.If, ast.While)):
                hit = _value_call(sub.test)
                if hit and not _annotated(mod, spans, sub.test.lineno,
                                          "jit-ok"):
                    findings.append(Finding(
                        checker="jit", path=mod.rel, line=sub.lineno,
                        rule="tracer-branch",
                        scope=f"branch@{hit}",
                        message=f"Python branch on {hit}(...) — "
                                f"data-dependent control flow breaks "
                                f"under jit (annotate '# jit-ok: <why>' "
                                f"if host-side by design)"))
            elif isinstance(sub, ast.Call):
                chain = attr_chain(sub.func)
                if chain and chain[-1] == "item" and not sub.args \
                        and len(chain) >= 2 \
                        and not _annotated(mod, spans, sub.lineno,
                                           "jit-ok"):
                    findings.append(Finding(
                        checker="jit", path=mod.rel, line=sub.lineno,
                        rule="tracer-item",
                        scope=f"item@{'.'.join(chain[:-1])}",
                        message=".item() forces device→host sync and "
                                "fails on tracers (annotate "
                                "'# jit-ok: <why>' if host-side)"))
                elif isinstance(sub.func, ast.Name) \
                        and sub.func.id == "float" and sub.args \
                        and _value_call(sub.args[0]) \
                        and not _annotated(mod, spans, sub.lineno,
                                           "jit-ok"):
                    findings.append(Finding(
                        checker="jit", path=mod.rel, line=sub.lineno,
                        rule="tracer-float", scope="float",
                        message="float(jnp.*(...)) concretizes a traced "
                                "value"))

        # compile-cache shape keys (only the configured cache file)
        if mod.rel != cfg.shape_cache_file:
            continue
        for sub in ast.walk(mod.tree):
            if not isinstance(sub, ast.Call):
                continue
            chain = attr_chain(sub.func)
            if not chain or chain[-1] != "add" \
                    or cfg.shape_cache_attr not in chain:
                continue
            fn = start = end = None
            for node, s, e in spans:
                if s <= sub.lineno <= e:
                    fn, start, end = node, s, e
                    break
            if fn is not None and \
                    mod.annotation(fn, "shape-static") is not None:
                continue
            if "shape-static" in mod.annotations_at(sub.lineno):
                continue
            body = "\n".join(lines[start - 1:end]) if fn is not None else ""
            if any(tok in body for tok in _POW2_EVIDENCE):
                continue
            findings.append(Finding(
                checker="jit", path=mod.rel, line=sub.lineno,
                rule="unbucketed-shape",
                scope=f"{fn.name if fn else '<module>'}@shape-cache",
                message=f"shape key enters {cfg.shape_cache_attr} with no "
                        f"power-of-two bucketing in the enclosing "
                        f"function (expected {_POW2_EVIDENCE}) — one "
                        f"compile per distinct length"))
    return findings
