"""CLI: ``python -m repro.analysis [--json out.json] [--checker NAME]
[--update-baseline]``.  Exit 0 iff every finding is baseline-suppressed;
stale baseline entries (fixed debt whose marker was not removed) also
fail, keeping the baseline shrink-only."""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import CHECKERS, default_repo_root, repo_config, run_all
from .baseline import (BASELINE_NAME, apply_baseline, load_baseline,
                       write_baseline)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant lint pass (see README 'Invariant "
                    "lint')")
    parser.add_argument("--repo-root", type=Path,
                        default=default_repo_root())
    parser.add_argument("--checker", action="append",
                        choices=[name for name, _ in CHECKERS],
                        help="run only this checker (repeatable)")
    parser.add_argument("--json", type=Path, metavar="PATH",
                        help="write the findings artifact here")
    parser.add_argument("--update-baseline", action="store_true",
                        help=f"rewrite {BASELINE_NAME} to suppress every "
                             f"current finding (requires --note)")
    parser.add_argument("--note", metavar="TEXT",
                        help="triage justification stamped on every new "
                             "baseline entry; required with "
                             "--update-baseline")
    args = parser.parse_args(argv)

    if args.update_baseline and not (args.note or "").strip():
        parser.error("--update-baseline requires --note: every baseline "
                     "entry is triaged debt and needs a justification")

    t0 = time.monotonic()
    cfg = repo_config(args.repo_root)
    findings = run_all(cfg, only=set(args.checker) if args.checker else None)

    if args.update_baseline:
        path = write_baseline(args.repo_root, findings, args.note)
        print(f"wrote {len(findings)} suppression(s) to {path}")
        return 0

    baseline = load_baseline(args.repo_root)
    stale = apply_baseline(findings, baseline)

    for f in findings:
        print(f.render())
    for fid in stale:
        print(f"stale baseline entry (fix landed — delete it from "
              f"{BASELINE_NAME}): {fid}")

    open_findings = [f for f in findings if not f.suppressed]
    suppressed = len(findings) - len(open_findings)
    dt = time.monotonic() - t0
    print(f"repro.analysis: {len(open_findings)} finding(s), "
          f"{suppressed} baseline-suppressed, {len(stale)} stale "
          f"baseline entr{'y' if len(stale) == 1 else 'ies'} "
          f"[{dt:.2f}s]")

    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps({
            "findings": [f.to_json() for f in findings],
            "stale_baseline": stale,
            "open": len(open_findings),
            "elapsed_s": round(dt, 3),
        }, indent=2) + "\n")

    return 1 if open_findings or stale else 0


if __name__ == "__main__":
    sys.exit(main())
