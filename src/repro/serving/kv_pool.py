"""Paged KV-cache block pool: fixed-size blocks, per-request block tables.

Instead of reserving a worst-case ``(L, B, max_len, K, D)`` cache slice per
decode slot, the engine owns one global pool of ``num_blocks`` fixed-size KV
blocks (``block_size`` tokens each).  Requests hold *block tables* — lists of
physical block ids in logical order — and the scheduler admits a request when
enough blocks are *free*, not when a worst-case slot is free.  Block 0 is a
reserved trash block: retired decode slots keep writing their (discarded)
rows there, so freeing a finished request's blocks can never be corrupted by
the in-flight batched decode step.

Lifecycle per request:
  * admission: ``reserve(n)`` the worst-case block count (prompt + budget)
  * prefill:   ``alloc_reserved`` the prompt's blocks
  * decode:    ``alloc_reserved(1)`` each time generation crosses a block
  * release:   ``free`` the allocated ids + ``unreserve`` the unused tail

Blocks are **refcounted** so a full prompt-prefix block can be shared by
several requests (prefix sharing): ``alloc_reserved`` hands a block out with
refcount 1, ``share`` increments it for each additional holder, and ``free``
decrements — the block only returns to the free list when the last holder
lets go, so a sharer can never free a block out from under another request.
Each allocation also bumps the block's **generation** counter; the engine's
prefix index stores ``(block_id, generation)`` pairs and treats an entry as
dead the moment the generation moves on, so a stale index entry can never
alias a block that was freed and re-allocated with different contents.

``CapacityError`` is the shared typed error for requests that can *never*
fit (engine ``_check_fits`` and scheduler admission both raise it), as
opposed to transient fullness, which just defers admission.

**Tiered mode** (``host_blocks > 0``) turns the device pool into the hot
tier of a cache hierarchy.  The engine's prefix index takes a refcounted
*hold* on every block it publishes (:meth:`KVBlockPool.hold`), so a shared
prefix stays device-resident — still seedable at zero copy — after its
last request releases it.  A held block whose only remaining holder is the
index is **demotable**: when :meth:`reserve` cannot be satisfied from the
free list alone, the pool demotes least-recently-idle demotable blocks
(the ``on_demote`` callback lets the engine spill their rows to the
:class:`HostTier` first), so admission counts ``free + demotable`` as
headroom (:attr:`available_blocks`).  The pinned set is implicit: blocks
held by live block tables have refcount > 1 and are never demotable, and
an in-flight spill captures immutable jax slices before the id is freed,
so reuse can never corrupt it.  Generation tags keep their existing
contract — a demoted id leaves ``_refs`` without bumping its generation,
so ``block_live`` goes False immediately and the next allocation bumps it,
which is what makes a stale fetch commit detectable.

The transfer state machine lives one layer up (the engine tracks pending
fetches per prefill job); the pool owns *placement* truth: which ids are
held, which are demotable and in what LRU order, and the host tier's
digest-keyed payload store.

``avail_epoch`` is a monotonic counter bumped whenever admission headroom
may have *grown* (a free, an unreserve, a block turning demotable).  The
scheduler uses it to cache a blocked queue head's failed admission check
and skip re-evaluating it until something actually changed.
"""
from __future__ import annotations

import threading
from typing import Any, Callable


class CapacityError(ValueError):
    """Request exceeds KV capacity (per-request table or whole pool)."""


class Tier:
    """A KV-block payload store below the device pool.

    Keys are the engine's chained prefix digests (`bytes`); payloads are
    opaque to the tier (in practice a dict of per-leaf numpy arrays for
    one block: k/v rows plus quantization scales when present).  ``load``
    returns ``None`` for a missing key instead of raising — a tier may
    evict under its own capacity pressure, and the engine falls back to
    recompute for whatever a fetch no longer finds.
    """

    name = "tier"
    capacity: int = 0

    def store(self, key: bytes, payload: Any) -> None:
        raise NotImplementedError

    def load(self, key: bytes) -> Any:
        raise NotImplementedError

    def drop(self, key: bytes) -> None:
        raise NotImplementedError

    def __contains__(self, key: bytes) -> bool:
        raise NotImplementedError

    @property
    def used(self) -> int:
        raise NotImplementedError


class HostTier(Tier):
    """Pinned host-memory tier: digest-keyed block payloads, LRU-evicted.

    ``begin_store`` marks a key *pending* the moment a spill is submitted
    (on the engine thread), so a concurrent lookup already counts it as
    resident and a fetch submitted behind it collects the real payload —
    the single transfer worker drains FIFO, so the store always lands
    first.  Pending entries are pinned (never LRU-evicted) until the
    worker fills them.  Thread-safe: the engine thread probes/marks while
    the transfer worker stores/loads.
    """

    name = "host"
    _PENDING = object()

    def __init__(self, capacity: int):
        assert capacity >= 1
        self.capacity = capacity
        self._lock = threading.Lock()
        self._data: dict[bytes, Any] = {}  # guarded-by: self._lock; LRU order
        self.stores = 0                    # guarded-by: self._lock
        self.loads = 0                     # guarded-by: self._lock
        self.evictions = 0                 # guarded-by: self._lock
        self.misses = 0                    # guarded-by: self._lock

    def begin_store(self, key: bytes) -> None:
        """Reserve ``key`` for an in-flight spill (pinned placeholder)."""
        with self._lock:
            if key not in self._data:
                self._data[key] = self._PENDING
                self._evict_over_capacity()

    def store(self, key: bytes, payload: Any) -> None:
        with self._lock:
            self._data.pop(key, None)        # refresh LRU position
            self._data[key] = payload
            self.stores += 1
            self._evict_over_capacity()

    # assumes-lock: self._lock
    def _evict_over_capacity(self) -> None:
        # oldest non-pending entries go first
        over = len(self._data) - self.capacity
        if over <= 0:
            return
        for k in [k for k, v in self._data.items()
                  if v is not self._PENDING][:over]:
            del self._data[k]
            self.evictions += 1

    def load(self, key: bytes) -> Any:
        with self._lock:
            payload = self._data.get(key)
            if payload is None or payload is self._PENDING:
                self.misses += 1
                return None
            del self._data[key]              # move-to-end = LRU touch
            self._data[key] = payload
            self.loads += 1
            return payload

    def drop(self, key: bytes) -> None:
        with self._lock:
            self._data.pop(key, None)

    def __contains__(self, key: bytes) -> bool:
        with self._lock:
            return key in self._data         # pending counts as resident

    @property
    def used(self) -> int:
        with self._lock:
            return len(self._data)

    @property
    def pending_count(self) -> int:
        """Keys pinned by an in-flight spill that never landed — nonzero
        after drain means a spill was submitted and its payload dropped
        (a leak the fault tests sweep for)."""
        with self._lock:
            return sum(v is self._PENDING for v in self._data.values())


class DiskTierStub(Tier):
    """Interface placeholder for a third tier below host memory.

    Exists so the tier stack has a named next rung (device -> host ->
    disk) without this PR committing to a file format or an eviction
    policy for it; any attempt to actually move payloads through it
    raises, which is the honest behaviour for a stub.
    """

    name = "disk"
    capacity = 0

    def store(self, key: bytes, payload: Any) -> None:
        raise NotImplementedError(
            "DiskTierStub is an interface placeholder: the disk tier has "
            "no storage backend yet (host tier is the only real tier)")

    def load(self, key: bytes) -> Any:
        raise NotImplementedError(
            "DiskTierStub is an interface placeholder: the disk tier has "
            "no storage backend yet (host tier is the only real tier)")

    def drop(self, key: bytes) -> None:
        pass

    def __contains__(self, key: bytes) -> bool:
        return False

    @property
    def used(self) -> int:
        return 0


class KVBlockPool:
    """Allocator for a global pool of fixed-size KV-cache blocks.

    ``num_blocks`` counts *usable* blocks; the backing device arrays have
    ``total_blocks = num_blocks + 1`` rows because id 0 is the trash block
    and is never handed out.
    """

    TRASH = 0

    def __init__(self, num_blocks: int, block_size: int = 16, *,
                 host_blocks: int = 0):
        assert num_blocks >= 1 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._lock = threading.Lock()
        # Hot-path structures are all O(1) per block: a LIFO list stack
        # (append/pop), dict refcounts, a dense generation list, and
        # insertion-ordered dict-sets for the held/demotable tracking —
        # no free-list or refcount scan anywhere in alloc/grow/free
        # (serving_bench's pool micro-bench pins this: per-op cost is
        # flat across pool sizes).
        # LIFO free stack of usable ids (1..num_blocks); 0 is trash.
        self._free: list[int] = \
            list(range(num_blocks, 0, -1))   # guarded-by: self._lock
        self._refs: dict[int, int] = {}      # guarded-by: self._lock
        self._gen = [0] * (num_blocks + 1)   # guarded-by: self._lock
        self._reserved = 0                   # guarded-by: self._lock
        self._peak_used = 0                  # guarded-by: self._lock
        # tiering (see module docstring): index-held ids, the demotable
        # subset in least-recently-idle order, and the host payload tier
        self._held: dict[int, None] = {}     # guarded-by: self._lock
        self._demotable: dict[int, None] = {}  # guarded-by: self._lock
        self.host: HostTier | None = \
            HostTier(host_blocks) if host_blocks > 0 else None
        # engine hook: spill these ids' rows to the host tier before the
        # pool frees them.  Called under the pool lock — the callback
        # must not call back into the pool.
        self.on_demote: Callable[[list[int]], None] | None = None
        self._demotions = 0                  # guarded-by: self._lock
        self._avail_epoch = 0                # guarded-by: self._lock

    # -- sizing ----------------------------------------------------------------

    @property
    def total_blocks(self) -> int:
        """Rows in the backing pool arrays (usable blocks + trash block)."""
        return self.num_blocks + 1

    @property
    def capacity(self) -> int:
        return self.num_blocks

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV rows."""
        return max(0, -(-tokens // self.block_size))

    def validate_rows(self, rows: int, rid=None) -> int:
        """The shared admission predicate: blocks for ``rows`` KV rows, or
        :class:`CapacityError` if they exceed the whole pool — engine
        ``_check_fits`` and scheduler ``submit`` both call this, so the
        check (and its message) cannot drift between the two."""
        blocks = self.blocks_for(rows)
        if blocks > self.capacity:
            raise CapacityError(
                f"request {rid}: {rows} KV rows need {blocks} blocks, "
                f"exceeding pool KV capacity of {self.capacity} blocks "
                f"({self.capacity * self.block_size} rows)")
        return blocks

    # -- accounting ------------------------------------------------------------

    @property
    def used_blocks(self) -> int:
        """Distinct allocated blocks (a shared block counts once)."""
        with self._lock:
            return len(self._refs)

    @property
    def free_blocks(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        with self._lock:
            return len(self._free) - self._reserved

    @property
    def reserved_blocks(self) -> int:
        with self._lock:
            return self._reserved

    @property
    def peak_used(self) -> int:
        """High-water mark of distinct allocated blocks."""
        with self._lock:
            return self._peak_used

    @property
    def utilization(self) -> float:
        """Peak allocated blocks as a fraction of capacity."""
        with self._lock:
            return self._peak_used / self.num_blocks

    def reset_peak(self) -> None:
        with self._lock:
            self._peak_used = len(self._refs)

    @property
    def demotions(self) -> int:
        """Lifetime count of index-held blocks demoted under pressure."""
        with self._lock:
            return self._demotions

    @property
    def demotable_count(self) -> int:
        """Blocks held only by the prefix index — freeable on demand (the
        scheduler's *restorable* headroom, and the router's)."""
        with self._lock:
            return len(self._demotable)

    @property
    def held_count(self) -> int:
        with self._lock:
            return len(self._held)

    @property
    def available_blocks(self) -> int:
        """What :meth:`reserve` can actually satisfy: strictly free blocks
        plus index-held blocks it may demote on demand."""
        with self._lock:
            return len(self._free) - self._reserved + len(self._demotable)

    @property
    def avail_epoch(self) -> int:
        """Monotonic headroom-growth counter (see module docstring); the
        scheduler's blocked-head admission cache keys on it."""
        with self._lock:
            return self._avail_epoch

    # -- lifecycle -------------------------------------------------------------

    def reserve(self, n: int) -> bool:
        """Promise ``n`` blocks to a request being admitted, demoting
        least-recently-idle index-held blocks if the free list alone
        cannot cover it (their rows spill to the host tier via the
        ``on_demote`` hook first).

        Returns False when the pool is transiently too full (caller defers
        admission); raises :class:`CapacityError` when ``n`` exceeds the
        whole pool, i.e. the request could never run.
        """
        if n > self.num_blocks:
            raise CapacityError(
                f"request needs {n} KV blocks but the pool only has "
                f"{self.num_blocks} (block_size={self.block_size})")
        with self._lock:
            shortfall = n - (len(self._free) - self._reserved)
            if shortfall > len(self._demotable):
                return False
            if shortfall > 0:
                self._demote_locked(shortfall)
            self._reserved += n
            return True

    # assumes-lock: self._lock
    def _demote_locked(self, k: int) -> None:
        """Free the ``k`` least-recently-idle demotable blocks (spilling
        their rows first via ``on_demote``).  Caller holds the lock; the
        callback must not re-enter the pool.  Generations are *not*
        bumped here — ``block_live`` goes False because the id leaves
        ``_refs``, and the next allocation bumps the generation, exactly
        like a normal free."""
        ids = []
        it = iter(self._demotable)
        for _ in range(k):
            ids.append(next(it))
        if self.on_demote is not None:
            self.on_demote(ids)
        for b in ids:
            assert self._refs.get(b) == 1, \
                f"demotable block {b} has refcount {self._refs.get(b)}"
            del self._refs[b]
            del self._held[b]
            del self._demotable[b]
            self._free.append(b)
        self._demotions += len(ids)

    def unreserve(self, n: int) -> None:
        with self._lock:
            assert self._reserved >= n, (self._reserved, n)
            self._reserved -= n
            if n:
                self._avail_epoch += 1

    def alloc_reserved(self, n: int) -> list[int]:
        """Materialize ``n`` previously reserved blocks as physical ids
        (each handed out with refcount 1 and a fresh generation)."""
        with self._lock:
            assert self._reserved >= n, \
                f"alloc of {n} blocks exceeds reservation {self._reserved}"
            assert len(self._free) >= n     # invariant: reserved <= free
            ids = [self._free.pop() for _ in range(n)]
            for b in ids:
                self._refs[b] = 1
                self._gen[b] += 1
            self._reserved -= n
            self._peak_used = max(self._peak_used, len(self._refs))
            return ids

    def share(self, ids: list[int]) -> None:
        """Add one holder to each (already allocated) block — the prefix-
        sharing path: a new request maps its leading table entries to
        blocks another request allocated.  A demotable block gaining a
        holder is hot again and leaves the demotion candidates."""
        with self._lock:
            for b in ids:
                if b not in self._refs:
                    raise ValueError(f"share of unallocated KV block {b}")
                self._refs[b] += 1
                self._demotable.pop(b, None)

    def free(self, ids: list[int]) -> list[int]:
        """Drop one holder per block; blocks whose last holder left return
        to the free list.  Returns the ids actually released (refcount hit
        zero).  Freeing an unallocated id raises.  An index-held block
        whose last *request* holder left (refcount back to the hold alone)
        becomes demotable instead of free — it stays device-resident and
        seedable until pool pressure demotes it."""
        released: list[int] = []
        with self._lock:
            for b in ids:
                refs = self._refs.get(b)
                if refs is None:
                    raise ValueError(f"double free of KV block {b}")
                if refs > 1:
                    self._refs[b] = refs - 1
                    if refs == 2 and b in self._held:
                        # idle now: last-touched order == demotable order
                        self._demotable.pop(b, None)
                        self._demotable[b] = None
                        self._avail_epoch += 1
                else:
                    del self._refs[b]
                    self._held.pop(b, None)      # defensive; a held block
                    self._demotable.pop(b, None)  # normally demotes instead
                    self._free.append(b)
                    released.append(b)
            if ids:
                # Any refcount decrement is a capacity event: even a
                # 2->1 drop on an unheld block raises the preemption
                # *gain* (reclaimable_count), so a blocked queue head
                # cached against the old epoch must be re-checked.
                self._avail_epoch += 1
        return released

    # -- tiering ---------------------------------------------------------------

    def hold(self, block_id: int) -> None:
        """The prefix index takes a holder on a just-published block, so
        it survives its requests' releases device-resident (demotable
        under pressure) instead of returning to the free list."""
        with self._lock:
            if block_id not in self._refs:
                raise ValueError(f"hold of unallocated KV block {block_id}")
            if block_id in self._held:
                raise ValueError(f"double hold of KV block {block_id}")
            self._refs[block_id] += 1
            self._held[block_id] = None

    def touch(self, ids: list[int]) -> None:
        """Refresh LRU position of any demotable ids among ``ids`` — a
        prefix lookup that seeds from an idle shared block makes it the
        *most* recently useful demotion candidate, not the next victim."""
        with self._lock:
            for b in ids:
                if b in self._demotable:
                    del self._demotable[b]
                    self._demotable[b] = None

    def release_provisional(self, ids: list[int]) -> None:
        """Return *provisionally grown* blocks — the rejected tail of a
        speculative verify step — and re-promise them to the caller.

        This is the rollback half of a grow-then-reject cycle: the engine
        ``alloc_reserved``s blocks for candidate KV rows before the verify
        pass, then hands back the ones past the accepted prefix.  Unlike
        :meth:`free`, the cycle must be *invisible*: each block's generation
        tag is rolled back to its pre-grow value (a provisional block never
        held published rows, so no prefix-index entry can alias it) and the
        blocks go back to being reserved rather than free, so another
        request can't race in and shrink the caller's worst-case budget.

        Provisional blocks are by construction unshared; passing a block
        with refcount != 1 (or a free block) raises without mutating.
        """
        with self._lock:
            for b in ids:
                refs = self._refs.get(b)
                if refs is None:
                    raise ValueError(
                        f"release_provisional of unallocated KV block {b}")
                if refs != 1:
                    raise ValueError(
                        f"release_provisional of shared KV block {b} "
                        f"(refcount {refs})")
            for b in ids:
                del self._refs[b]
                self._gen[b] -= 1
                self._free.append(b)
            self._reserved += len(ids)

    # -- migration export --------------------------------------------------------

    def export_blocks(self, ids: list[int]) -> list[int]:
        """Pin ``ids`` for an in-flight prefill→decode migration and
        return their generation tags, in order.

        Adds one holder per block (like :meth:`share`) so the source
        pool can neither free nor re-allocate a migrating block while
        its rows are in flight — the export hold is what keeps the
        captured device slices generation-stable evidence instead of a
        race against the releasing request.  The caller drops the export
        with a plain :meth:`free` once the transfer commits or fails;
        the returned generations let the receiver side double-check
        :meth:`block_live` before admitting the payload.  Exporting the
        trash block or an unallocated id raises without mutating.
        """
        with self._lock:
            for b in ids:
                if b == self.TRASH:
                    raise ValueError("export of trash KV block 0")
                if b not in self._refs:
                    raise ValueError(f"export of unallocated KV block {b}")
            for b in ids:
                self._refs[b] += 1
                self._demotable.pop(b, None)
            return [self._gen[b] for b in ids]

    # -- prefix-index support ----------------------------------------------------

    def refcount(self, block_id: int) -> int:
        """Current holder count (0 if the block is free)."""
        with self._lock:
            return self._refs.get(block_id, 0)

    def releasable_count(self, ids: list[int]) -> int:
        """How many of ``ids`` would actually return to the free list if
        their holder freed them now (refcount exactly 1) — the preemption
        gain estimate for a victim whose blocks may be shared out."""
        with self._lock:
            return sum(self._refs.get(b, 0) == 1 for b in ids)

    def reclaimable_count(self, ids: list[int]) -> int:
        """Tier-aware preemption gain: blocks a victim's free would return
        to the free list (refcount 1) *plus* blocks it would turn
        demotable (refcount 2 with one holder being the prefix index) —
        either way the pool can hand them to the preemptor."""
        with self._lock:
            out = 0
            for b in ids:
                refs = self._refs.get(b, 0)
                if refs == 1 or (refs == 2 and b in self._held):
                    out += 1
            return out

    def generation(self, block_id: int) -> int:
        """Allocation generation of ``block_id`` (bumped per allocation)."""
        with self._lock:
            return self._gen[block_id]

    def block_live(self, block_id: int, gen: int) -> bool:
        """True iff ``block_id`` is still allocated *and* still the same
        allocation the caller tagged — the prefix index's validity check:
        a block that was freed and re-allocated has a newer generation and
        must not be shared as if it still held the old prefix rows."""
        with self._lock:
            return block_id in self._refs and self._gen[block_id] == gen

    # -- fault-tolerance audit ---------------------------------------------------

    def leak_report(self) -> dict[str, int]:
        """Leak sweep for the fault tests: after a full drain (every
        request DONE or FAILED and every slot retired), the only
        legitimate surviving allocations are prefix-index holds — each
        with refcount exactly 1 (the hold itself).  Anything else is a
        leaked request holder, a stranded reservation, or a spill pin
        that never landed.  Returns a dict of violation counts; all-zero
        means leak-free."""
        with self._lock:
            unheld = [b for b in self._refs if b not in self._held]
            held_over = [b for b in self._held if self._refs.get(b, 0) != 1]
            report = {
                # allocated blocks no index hold accounts for
                "unheld_blocks": len(unheld),
                # held blocks some request still refcounts (or a hold on
                # a freed id)
                "held_with_extra_refs": len(held_over),
                "reserved_blocks": self._reserved,
            }
        report["host_pending"] = (self.host.pending_count
                                  if self.host is not None else 0)
        return report

    def assert_leak_free(self) -> None:
        """Raise with the full report when :meth:`leak_report` is dirty."""
        report = self.leak_report()
        if any(report.values()):
            raise AssertionError(f"KV pool leak after drain: {report}")
