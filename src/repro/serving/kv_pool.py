"""Paged KV-cache block pool: fixed-size blocks, per-request block tables.

Instead of reserving a worst-case ``(L, B, max_len, K, D)`` cache slice per
decode slot, the engine owns one global pool of ``num_blocks`` fixed-size KV
blocks (``block_size`` tokens each).  Requests hold *block tables* — lists of
physical block ids in logical order — and the scheduler admits a request when
enough blocks are *free*, not when a worst-case slot is free.  Block 0 is a
reserved trash block: retired decode slots keep writing their (discarded)
rows there, so freeing a finished request's blocks can never be corrupted by
the in-flight batched decode step.

Lifecycle per request:
  * admission: ``reserve(n)`` the worst-case block count (prompt + budget)
  * prefill:   ``alloc_reserved`` the prompt's blocks
  * decode:    ``alloc_reserved(1)`` each time generation crosses a block
  * release:   ``free`` the allocated ids + ``unreserve`` the unused tail

Blocks are **refcounted** so a full prompt-prefix block can be shared by
several requests (prefix sharing): ``alloc_reserved`` hands a block out with
refcount 1, ``share`` increments it for each additional holder, and ``free``
decrements — the block only returns to the free list when the last holder
lets go, so a sharer can never free a block out from under another request.
Each allocation also bumps the block's **generation** counter; the engine's
prefix index stores ``(block_id, generation)`` pairs and treats an entry as
dead the moment the generation moves on, so a stale index entry can never
alias a block that was freed and re-allocated with different contents.

``CapacityError`` is the shared typed error for requests that can *never*
fit (engine ``_check_fits`` and scheduler admission both raise it), as
opposed to transient fullness, which just defers admission.
"""
from __future__ import annotations

import threading


class CapacityError(ValueError):
    """Request exceeds KV capacity (per-request table or whole pool)."""


class KVBlockPool:
    """Allocator for a global pool of fixed-size KV-cache blocks.

    ``num_blocks`` counts *usable* blocks; the backing device arrays have
    ``total_blocks = num_blocks + 1`` rows because id 0 is the trash block
    and is never handed out.
    """

    TRASH = 0

    def __init__(self, num_blocks: int, block_size: int = 16):
        assert num_blocks >= 1 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._lock = threading.Lock()
        # LIFO free stack of usable ids (1..num_blocks); 0 is trash.
        self._free: list[int] = list(range(num_blocks, 0, -1))
        self._refs: dict[int, int] = {}      # allocated id -> holder count
        self._gen = [0] * (num_blocks + 1)   # bumped on every allocation
        self._reserved = 0
        self.peak_used = 0

    # -- sizing ----------------------------------------------------------------

    @property
    def total_blocks(self) -> int:
        """Rows in the backing pool arrays (usable blocks + trash block)."""
        return self.num_blocks + 1

    @property
    def capacity(self) -> int:
        return self.num_blocks

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV rows."""
        return max(0, -(-tokens // self.block_size))

    def validate_rows(self, rows: int, rid=None) -> int:
        """The shared admission predicate: blocks for ``rows`` KV rows, or
        :class:`CapacityError` if they exceed the whole pool — engine
        ``_check_fits`` and scheduler ``submit`` both call this, so the
        check (and its message) cannot drift between the two."""
        blocks = self.blocks_for(rows)
        if blocks > self.capacity:
            raise CapacityError(
                f"request {rid}: {rows} KV rows need {blocks} blocks, "
                f"exceeding pool KV capacity of {self.capacity} blocks "
                f"({self.capacity * self.block_size} rows)")
        return blocks

    # -- accounting ------------------------------------------------------------

    @property
    def used_blocks(self) -> int:
        """Distinct allocated blocks (a shared block counts once)."""
        with self._lock:
            return len(self._refs)

    @property
    def free_blocks(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        with self._lock:
            return len(self._free) - self._reserved

    @property
    def reserved_blocks(self) -> int:
        with self._lock:
            return self._reserved

    @property
    def utilization(self) -> float:
        """Peak allocated blocks as a fraction of capacity."""
        return self.peak_used / self.num_blocks

    def reset_peak(self) -> None:
        with self._lock:
            self.peak_used = len(self._refs)

    # -- lifecycle -------------------------------------------------------------

    def reserve(self, n: int) -> bool:
        """Promise ``n`` blocks to a request being admitted.

        Returns False when the pool is transiently too full (caller defers
        admission); raises :class:`CapacityError` when ``n`` exceeds the
        whole pool, i.e. the request could never run.
        """
        if n > self.num_blocks:
            raise CapacityError(
                f"request needs {n} KV blocks but the pool only has "
                f"{self.num_blocks} (block_size={self.block_size})")
        with self._lock:
            if len(self._free) - self._reserved < n:
                return False
            self._reserved += n
            return True

    def unreserve(self, n: int) -> None:
        with self._lock:
            assert self._reserved >= n, (self._reserved, n)
            self._reserved -= n

    def alloc_reserved(self, n: int) -> list[int]:
        """Materialize ``n`` previously reserved blocks as physical ids
        (each handed out with refcount 1 and a fresh generation)."""
        with self._lock:
            assert self._reserved >= n, \
                f"alloc of {n} blocks exceeds reservation {self._reserved}"
            assert len(self._free) >= n     # invariant: reserved <= free
            ids = [self._free.pop() for _ in range(n)]
            for b in ids:
                self._refs[b] = 1
                self._gen[b] += 1
            self._reserved -= n
            self.peak_used = max(self.peak_used, len(self._refs))
            return ids

    def share(self, ids: list[int]) -> None:
        """Add one holder to each (already allocated) block — the prefix-
        sharing path: a new request maps its leading table entries to
        blocks another request allocated."""
        with self._lock:
            for b in ids:
                if b not in self._refs:
                    raise ValueError(f"share of unallocated KV block {b}")
                self._refs[b] += 1

    def free(self, ids: list[int]) -> list[int]:
        """Drop one holder per block; blocks whose last holder left return
        to the free list.  Returns the ids actually released (refcount hit
        zero).  Freeing an unallocated id raises."""
        released: list[int] = []
        with self._lock:
            for b in ids:
                refs = self._refs.get(b)
                if refs is None:
                    raise ValueError(f"double free of KV block {b}")
                if refs > 1:
                    self._refs[b] = refs - 1
                else:
                    del self._refs[b]
                    self._free.append(b)
                    released.append(b)
        return released

    def release_provisional(self, ids: list[int]) -> None:
        """Return *provisionally grown* blocks — the rejected tail of a
        speculative verify step — and re-promise them to the caller.

        This is the rollback half of a grow-then-reject cycle: the engine
        ``alloc_reserved``s blocks for candidate KV rows before the verify
        pass, then hands back the ones past the accepted prefix.  Unlike
        :meth:`free`, the cycle must be *invisible*: each block's generation
        tag is rolled back to its pre-grow value (a provisional block never
        held published rows, so no prefix-index entry can alias it) and the
        blocks go back to being reserved rather than free, so another
        request can't race in and shrink the caller's worst-case budget.

        Provisional blocks are by construction unshared; passing a block
        with refcount != 1 (or a free block) raises without mutating.
        """
        with self._lock:
            for b in ids:
                refs = self._refs.get(b)
                if refs is None:
                    raise ValueError(
                        f"release_provisional of unallocated KV block {b}")
                if refs != 1:
                    raise ValueError(
                        f"release_provisional of shared KV block {b} "
                        f"(refcount {refs})")
            for b in ids:
                del self._refs[b]
                self._gen[b] -= 1
                self._free.append(b)
            self._reserved += len(ids)

    # -- prefix-index support ----------------------------------------------------

    def refcount(self, block_id: int) -> int:
        """Current holder count (0 if the block is free)."""
        with self._lock:
            return self._refs.get(block_id, 0)

    def releasable_count(self, ids: list[int]) -> int:
        """How many of ``ids`` would actually return to the free list if
        their holder freed them now (refcount exactly 1) — the preemption
        gain estimate for a victim whose blocks may be shared out."""
        with self._lock:
            return sum(self._refs.get(b, 0) == 1 for b in ids)

    def generation(self, block_id: int) -> int:
        """Allocation generation of ``block_id`` (bumped per allocation)."""
        with self._lock:
            return self._gen[block_id]

    def block_live(self, block_id: int, gen: int) -> bool:
        """True iff ``block_id`` is still allocated *and* still the same
        allocation the caller tagged — the prefix index's validity check:
        a block that was freed and re-allocated has a newer generation and
        must not be shared as if it still held the old prefix rows."""
        with self._lock:
            return block_id in self._refs and self._gen[block_id] == gen
