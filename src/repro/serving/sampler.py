"""Token samplers (host-side, numpy — decode logits are tiny).

Samplers are small objects with two entry points:

  * ``sampler(logits_1d) -> int`` — single-request call (back-compat).
  * ``sampler.sample(logits_2d) -> (B,) int64`` — vectorized batch call;
    this is what the continuous-batching engine uses, so the per-step
    sampling cost is a couple of numpy array ops for the whole decode
    batch instead of a Python loop per request.

``batch_key`` groups decode slots that can share one vectorized call:
stateless samplers (greedy) group globally; stateful ones (temperature,
which owns an rng for per-request determinism) group per instance.
"""
from __future__ import annotations

import numpy as np


class Sampler:
    """Base sampler: implement `sample` (vectorized); `__call__` wraps it."""

    def __call__(self, logits: np.ndarray) -> int:
        return int(self.sample(np.asarray(logits)[None])[0])

    def sample(self, logits: np.ndarray) -> np.ndarray:
        """logits: (B, V) -> (B,) sampled token ids."""
        raise NotImplementedError

    @property
    def batch_key(self):
        """Slots whose samplers share a key are sampled in one batch call."""
        return id(self)


class Greedy(Sampler):
    batch_key = "greedy"    # stateless: all greedy slots share one argmax

    def sample(self, logits: np.ndarray) -> np.ndarray:
        return np.argmax(logits, axis=-1)


class Temperature(Sampler):
    """Temperature + top-k via the Gumbel-max trick (one vectorized argmax
    instead of per-row softmax/choice)."""

    def __init__(self, t: float = 1.0, *, top_k: int = 0, seed: int = 0):
        self.t = t
        self.top_k = top_k
        self.rng = np.random.default_rng(seed)

    def sample(self, logits: np.ndarray) -> np.ndarray:
        x = logits.astype(np.float64) / max(self.t, 1e-6)
        if self.top_k:
            kth = np.partition(x, -self.top_k, axis=-1)[:, -self.top_k, None]
            x = np.where(x < kth, -np.inf, x)
        g = self.rng.gumbel(size=x.shape)
        return np.argmax(x + g, axis=-1)


def greedy_accept_prefix(verify_logits: np.ndarray, drafts: np.ndarray):
    """Vectorized longest-prefix greedy acceptance for speculative decoding.

    verify_logits: (B, k+1, V) target logits after feeding ``[t_0,
    d_1 .. d_k]`` per slot — row ``j`` is the target distribution given
    the context plus ``t_0, d_1 .. d_j``.  drafts: (B, k) the drafter's
    proposals.  Draft ``d_{j+1}`` is accepted iff it equals the target's
    argmax at row ``j`` *and* every earlier draft was accepted — exactly
    the tokens vanilla greedy decode would have produced, which is what
    makes speculative output bit-identical.

    Returns ``(accepted, targets)``: accepted (B,) counts of accepted
    drafts in [0, k]; targets (B, k+1) the target argmax chain (row ``m``
    with ``m = accepted`` is the slot's next pending greedy token).
    """
    targets = np.argmax(verify_logits, axis=-1)
    match = drafts == targets[:, :-1]
    k = drafts.shape[1]
    accepted = np.where(match.all(axis=1), k, np.argmax(~match, axis=1))
    return accepted.astype(np.int64), targets


def greedy() -> Sampler:
    return Greedy()


def temperature(t: float = 1.0, *, top_k: int = 0, seed: int = 0) -> Sampler:
    return Temperature(t, top_k=top_k, seed=seed)
