"""Token samplers (host-side, numpy — decode logits are tiny)."""
from __future__ import annotations

from typing import Callable

import numpy as np

Sampler = Callable[[np.ndarray], int]


def greedy() -> Sampler:
    def fn(logits: np.ndarray) -> int:
        return int(np.argmax(logits))
    return fn


def temperature(t: float = 1.0, *, top_k: int = 0, seed: int = 0) -> Sampler:
    rng = np.random.default_rng(seed)

    def fn(logits: np.ndarray) -> int:
        x = logits.astype(np.float64) / max(t, 1e-6)
        if top_k:
            kth = np.partition(x, -top_k)[-top_k]
            x = np.where(x < kth, -np.inf, x)
        x = x - x.max()
        p = np.exp(x)
        p /= p.sum()
        return int(rng.choice(len(p), p=p))
    return fn
