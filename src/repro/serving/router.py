"""Replica router: prefix-affinity dispatch, block-aware load, work stealing.

The paper's headline result is a *multi-VPU* configuration — the fleet,
not the single chip, is the unit of performance — and datacenter inference
lives or dies on how requests are placed across accelerators (see the TPU
datacenter analysis in PAPERS.md).  This module owns cross-replica
placement policy for the continuous-batching serving stack; each replica
is still one :class:`~repro.serving.engine.ServingEngine` driven through
`repro.core.offload`'s split-phase protocol (non-blocking submit,
out-of-order drain, deadline straggler reissue), exactly as before — only
the *policy* deciding which replica gets a request changed:

  * **prefix-affinity dispatch** — the router keeps a fleet-level index of
    full-leading-block prompt digests (the same chained-digest scheme as
    each engine's per-replica prefix index; see
    :func:`~repro.serving.engine.prefix_digests`) mapping digest ->
    replica.  A request routes to the replica already holding its longest
    prompt prefix, so cache-seeded prefill fires *fleet-wide* instead of
    only on whichever replica least-loaded luck assigned — without this,
    the PR-3/PR-4 prefix-sharing and seeded-prefill wins evaporate the
    moment a second replica exists.
  * **block-aware load** — a replica's load is its
    :class:`~repro.serving.scheduler.LoadSnapshot` (free decode slots,
    free KV blocks, queued prefill tokens) rather than its raw request
    count, so a blocks-starved replica stops winning placement ties.
  * **work stealing** — a replica that goes idle (free slots + blocks,
    empty queue) pulls still-QUEUED requests off the back of the most
    backlogged peer's priority heap
    (:meth:`~repro.serving.scheduler.ContinuousScheduler.steal`:
    heap invariants, ``submitted_at``, priority, and SLO deadline all
    preserved).  Affinity concentrates; stealing is the relief valve —
    and the offload layer's ``WorkItem.complete`` first-wins commit keeps
    a steal racing a deadline reissue safe: whichever copy finishes first
    is the result, the other is discarded on completion.

  * **disaggregated prefill/decode** — replicas constructed with
    ``role="prefill"`` run chunked prefill at full budget with no decode
    slots contending; on completion the prompt's KV blocks *migrate* to
    the best-placed decode-capable replica as a
    ``("migrate", rid, keys, tables, leaves, gens)`` payload on a
    dedicated split-phase offload channel, and the receiver adopts them
    via :meth:`ServingEngine.adopt_blocks` — entering DECODE without
    recomputing a single prompt token.  Latency-bound decode and
    throughput-bound prefill stop fighting for the same slots and
    blocks; a failed migration (the ``kv.migrate`` fault site) releases
    the source's export pins and retries from the bare prompt through
    the same bounded retry path as any other failure.

The router is also the fleet's fault boundary (a sub-1W fleet fails one
chip at a time, by design): it tracks per-replica health
(HEALTHY -> DEGRADED -> DEAD), quarantines dead replicas out of
placement / affinity / stealing, and reissues their queued and in-flight
requests to survivors with bounded retries — riding the same
``WorkItem.complete`` first-wins commit as straggler reissue, so a retry
racing a late original is safe and retries exhausted means a typed
FAILED terminal, never a hang.

``MultiReplicaEngine`` (the PR-1 request-count least-loaded dispatcher)
survives as the routing A/B baseline: a :class:`ReplicaRouter` with every
mechanism switched off.
"""
from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass
from itertools import islice
from typing import Callable

from repro.core.offload import (KVBlockTarget, OffloadEngine, Target,
                                WorkError, WorkItem)
from repro.serving.engine import ServeStats, ServingEngine, prefix_digests
from repro.serving.faults import (DeadlineExceeded, ExecutorCrash,
                                  FaultError, ShedError)
from repro.serving.kv_pool import CapacityError
from repro.serving.scheduler import (LoadSnapshot, Request, RequestState)


class ReplicaHealth(enum.Enum):
    """One replica's standing in the fleet.  DEGRADED (a request-level
    fault was observed) still serves traffic; DEAD (its executor crashed)
    is quarantined out of placement, affinity, and stealing."""
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DEAD = "dead"


class ReplicaTarget(Target):
    """Adapter: one continuous-batching replica as an offload Target.

    `load_tensor` (the paper's mvncLoadTensor) admits a request clone into
    the replica's scheduler and returns immediately; the replica's executor
    thread plays the role of the per-NCS worker, and `WorkItem.complete`
    fires when the request's last token is emitted.  `queue_depth` exposes
    scheduler load (queued + occupied slots) for the offload layer's
    generic least-loaded paths (straggler reissue picks by it); routed
    placement scores on the richer :meth:`ServingEngine.load_snapshot`.
    """

    # set by the router: (item, failed_request, replica_name) -> bool.
    # True = the request was reissued on a survivor; leave the item open
    # for that clone's first-wins commit.
    fail_handler: Callable[[WorkItem, Request, str], bool] | None = None

    def __init__(self, engine: ServingEngine, name: str,
                 tdp_watts: float = 1.0):
        self.engine = engine
        self.name = name
        self.tdp_watts = tdp_watts

    def open(self) -> None:
        self.busy = False
        self.engine.start()

    def close(self) -> None:
        # any captured executor crash was already routed through the
        # retry path; re-raising it here would abort teardown of the
        # remaining healthy replicas
        self.engine.stop(raise_failure=False)

    def dispatch(self, item: WorkItem, req: Request) -> None:
        """Admit ``req`` on this replica, wiring completion back to
        ``item``.  A FAILED terminal is offered to the router's
        ``fail_handler`` first; only an unhandled failure commits, so the
        item always resolves — retried elsewhere or typed-FAILED.  Raises
        when this replica refuses admission (dead, shedding, capacity)."""
        def done(r: Request, item: WorkItem = item) -> None:
            # a disaggregated request finishes (or fails) on whichever
            # replica *adopted* it, not the one this closure dispatched
            # to — r.replica follows the request, so failures are charged
            # to the engine that actually terminated it
            if (r.state is RequestState.FAILED
                    and self.fail_handler is not None
                    and self.fail_handler(item, r,
                                          r.replica or self.name)):
                return
            item.complete(r, self.name)
        self.engine.submit(req, on_finish=done)

    def load_tensor(self, item: WorkItem) -> WorkItem:
        req = item.payload.clone()      # reissue-safe: first clone wins
        try:
            self.dispatch(item, req)
        except Exception as e:  # noqa: BLE001 — dead or shedding replica:
            # fail the clone and route it exactly like an in-flight
            # failure (retry on a survivor, else typed FAILED terminal)
            req.state = RequestState.FAILED
            req.error = e
            if not (self.fail_handler is not None
                    and self.fail_handler(item, req, self.name)):
                item.complete(req, self.name)
        return item

    @property
    def queue_depth(self) -> int:
        return self.engine.load


@dataclass
class RouterStats:
    """Lifetime placement counters (monotonic, like ``ServeStats`` totals);
    :meth:`ReplicaRouter.serve` windows them into the returned stats."""
    affinity_hits: int = 0      # requests routed onto a resident prefix
    affinity_blocks: int = 0    # full prefix blocks those hits landed on
    affinity_fallbacks: int = 0  # hits declined (owner overloaded)
    steals: int = 0             # requests migrated to an idle replica
    retries: int = 0            # failed requests reissued to a survivor
    replica_failures: int = 0   # replicas quarantined DEAD (crashed)
    rebalance_errors: int = 0   # rebalance ticks that raised (and were
    #                             contained; serve() re-surfaces the last)
    migrations: int = 0         # disagg: prefills adopted by a decode peer
    migration_failures: int = 0  # disagg: migrations dropped/refused (the
    #                              request re-enters the retry path)


@dataclass
class _Migration:
    """One in-flight prefill→decode KV migration.  The offload payload
    stays the documented self-describing 6-tuple
    (``("migrate", rid, keys, tables, leaves, gens)``); everything the
    payload must *not* carry across the core layer — the live request
    object, its token stream, the final-chunk logits, and the source
    pool whose export holds pin the blocks — rides here, keyed by the
    identity of the payload's ``tables`` list (unique per migration and
    kept alive by this record, so the key cannot be reused mid-flight)."""
    req: Request
    tokens: object              # np.ndarray prompt stream for the receiver
    last: object                # np.ndarray final-chunk logits (V,)
    src: ServingEngine          # holds the export pins until completion
    export_ids: list            # pinned source block ids, table order
    tables: list                # the payload's tables list (the dict key)
    dest: int                   # replica index chosen at handoff


class _MigrationAdapter:
    """Duck-typed 'tier' a :class:`~repro.core.offload.KVBlockTarget`
    drives for the migrate payload family: ``adopt`` lands one migrated
    prefill on its decode replica via
    :meth:`ServingEngine.adopt_blocks`.  Before admitting, it checks the
    generation evidence the export holds promise — ``block_live`` going
    False for an exported block would mean the captured rows' id was
    freed and re-allocated mid-flight, which the hold exists to prevent,
    so a failure here is a broken invariant, not a race to tolerate."""

    name = "migration"

    def __init__(self, router: "ReplicaRouter", engine: ServingEngine):
        self.router = router
        self.engine = engine

    def adopt(self, rid, keys, tables, blocks, gens):
        with self.router._mig_lock:
            rec = self.router._mig_records.get(id(tables))
        if rec is None:          # record reaped by a concurrent completion
            return None          # (first-wins: this copy lost)
        for bid, gen in zip(rec.export_ids, gens):
            if not rec.src.pool.block_live(bid, gen):
                raise RuntimeError(
                    f"migration of request {rid}: exported block {bid} no "
                    f"longer holds generation {gen} — export pin broken")
        return self.engine.adopt_blocks(rec.req, keys, rec.tokens, blocks,
                                        rec.last)


class ReplicaRouter:
    """Places individual requests across continuous-batching replicas.

    Placement policy = affinity, then block-aware score:

    1. With ``affinity`` on, look the prompt's chained block digests up in
       the fleet prefix index, deepest first; the replica owning the
       longest match wins — unless its queue has blown past
       ``affinity_queue_cap`` (owner saturated: a cache hit is not worth
       unbounded head-of-line wait; fall through to the load score).
    2. Otherwise pick the replica with, in order: immediate capacity (a
       free slot *and* enough free blocks for this request), the fewest
       queued prefill tokens, the most free KV blocks.  With
       ``block_aware=False`` this degrades to the PR-1 policy (raw
       request count).

    With ``steal`` on, a background rebalance thread runs while
    :meth:`serve` is in flight: each tick, every idle replica (free slot,
    empty queue) steals the lowest-ranked queued request it has block
    headroom for from the most backlogged peer.  Dispatch, drain, and
    straggler reissue ride `repro.core.offload` unchanged via its
    placement hook (``scheduler=callable``).
    """

    def __init__(self, replicas: list[ServingEngine], *,
                 affinity: bool = True, steal: bool = True,
                 block_aware: bool = True,
                 affinity_queue_cap: int | None = None,
                 steal_interval_s: float = 0.005,
                 deadline_s: float | None = None,
                 max_retries: int = 2,
                 prefix_index_cap: int = 65536):
        assert replicas, "router needs at least one replica"
        self.replicas = replicas
        self.max_retries = max_retries
        self.targets = [ReplicaTarget(e, name=f"replica{i}")
                        for i, e in enumerate(replicas)]
        self._target_index = {t.name: i for i, t in enumerate(self.targets)}
        for t in self.targets:
            t.fail_handler = self._on_request_failed
        # affinity needs every replica on one digest scheme: paged KV and
        # a common block size (else "same prefix" means different blocks)
        paged = all(e.pool is not None for e in replicas)
        sizes = {e.block_size for e in replicas}
        if affinity and paged and len(sizes) > 1:
            raise ValueError(
                f"prefix-affinity routing needs one block size fleet-wide, "
                f"got {sorted(sizes)}; disable affinity or align the pools")
        self.affinity = affinity and paged
        self.block_size = sizes.pop() if len(sizes) == 1 else None
        self.steal = steal
        self.block_aware = block_aware
        # default cap: 4x the owner's slots — deep enough that a shared-
        # prefix burst stays co-located (the whole point), bounded enough
        # that one hot prefix cannot wedge a replica while peers idle
        # (and with stealing on, the queue drains from the back anyway)
        self.affinity_queue_cap = affinity_queue_cap
        self.steal_interval_s = steal_interval_s
        self.deadline_s = deadline_s
        # placement counters are bumped on the dispatch thread (_select)
        # *and* the rebalance thread (_rebalance_once) and windowed by
        # serve() — unlocked `+=` across those threads drops increments
        self._stats_lock = threading.Lock()
        self.stats = RouterStats()           # guarded-by: self._stats_lock
        self._health = [ReplicaHealth.HEALTHY  # guarded-by: self._stats_lock
                        for _ in replicas]
        self._rebalance_exc: BaseException | None = None  # guarded-by: self._stats_lock
        # fleet prefix index: digest of blocks 0..j -> replica that last
        # computed (or was routed) that prefix.  A *hint*, not truth: a
        # replica may have evicted the blocks (its own index validates
        # against the pool at admission), staleness only costs recompute.
        # Confined to the dispatch thread (serve -> offload submit ->
        # _place -> _select/_register); the rebalance thread never reads
        # it, so it needs no lock — the checker enforces the confinement.
        self._prefix_owner: dict[bytes, int] = {}  # owned-by: dispatch-thread
        self._prefix_cap = prefix_index_cap
        self._steal_stop = threading.Event()
        self._steal_thread: threading.Thread | None = None
        # engine names (stamped on requests for failure attribution) may
        # differ from target names; resolve both in the failure path
        self._engine_index = {
            name: i for i, e in enumerate(replicas)
            if (name := getattr(e, "name", None))}
        # disaggregated fleet: prefill-role replicas hand finished
        # prompts to the migration channel; decode-capable replicas
        # (role decode/mixed) adopt them.  Roles are placement policy —
        # any replica can still run either phase if asked.
        roles = [getattr(e, "role", "mixed") for e in replicas]
        self._prefill_set = frozenset(
            i for i, r in enumerate(roles) if r == "prefill")
        self._prefill_capable = frozenset(
            i for i, r in enumerate(roles) if r != "decode")
        self._decode_capable = [i for i, r in enumerate(roles)
                                if r != "prefill"]
        self.disaggregated = bool(self._prefill_set)
        self._mig_io = None
        if self.disaggregated:
            if not self._decode_capable:
                raise ValueError(
                    "a disaggregated fleet needs at least one decode-"
                    "capable (role='decode' or 'mixed') replica to adopt "
                    "migrated prefills")
            if not paged:
                raise ValueError("disaggregated serving needs paged KV on "
                                 "every replica (migration moves pool "
                                 "blocks)")
            if self.block_size is None:
                raise ValueError("KV migration needs one block size "
                                 "fleet-wide (blocks land id-for-id in "
                                 "the receiver's pool)")
            dtypes = {e.cache_dtype for e in replicas}
            if len(dtypes) > 1:
                raise ValueError(
                    f"KV migration needs one cache dtype fleet-wide — "
                    f"adopt casts rows on write, which would silently "
                    f"corrupt quantized scales across {sorted(dtypes)}")
            self._mig_lock = threading.Lock()
            self._mig_records: dict[int, _Migration] = {}  # guarded-by: self._mig_lock
            self._mig_pending = 0                          # guarded-by: self._mig_lock
            # one migrate target per decode-capable replica; _place_migration
            # routes each payload to the destination its record chose
            self._mig_target_index: dict[int, int] = {}
            mig_targets = []
            for k in self._decode_capable:
                e = self.replicas[k]
                tgt = KVBlockTarget(_MigrationAdapter(self, e),
                                    name=f"migrate-{k}")
                if e.fault_plan is not None:
                    # kv.migrate probe fires on the migration worker,
                    # charged to the *destination* engine's plan filters
                    tgt.fault_hook = (
                        lambda item, e=e:
                        e._fault("kv.migrate",
                                 rid=item.payload[1]) == "drop")
                self._mig_target_index[k] = len(mig_targets)
                mig_targets.append(tgt)
            self._mig_io = OffloadEngine(mig_targets,
                                         scheduler=self._place_migration)
            self._mig_io.__enter__()       # daemon workers; router-lifetime
            for i in self._prefill_set:
                self.replicas[i]._on_prefilled = (
                    lambda req, keys, ids, gens, leaves, tokens, last,
                    i=i: self._migrate(i, req, keys, ids, gens, leaves,
                                       tokens, last))

    # -- replica health + failure routing --------------------------------------

    def health(self) -> list[ReplicaHealth]:
        with self._stats_lock:
            return list(self._health)

    def _healthy(self) -> list[int]:
        """Replica indices still eligible for traffic (not DEAD)."""
        with self._stats_lock:
            return [i for i, h in enumerate(self._health)
                    if h is not ReplicaHealth.DEAD]

    def _mark_degraded(self, i: int) -> None:
        with self._stats_lock:
            if self._health[i] is ReplicaHealth.HEALTHY:
                self._health[i] = ReplicaHealth.DEGRADED

    def _mark_dead(self, i: int) -> None:
        with self._stats_lock:
            if self._health[i] is ReplicaHealth.DEAD:
                return
            self._health[i] = ReplicaHealth.DEAD
            self.stats.replica_failures += 1

    def _heartbeat(self) -> None:
        """Quarantine any replica whose executor has died.  Runs on the
        rebalance thread each tick; the failure-routing path below also
        detects death inline, so a steal-free router is covered too."""
        for i, e in enumerate(self.replicas):
            if e.failure is not None:
                self._mark_dead(i)

    def _on_request_failed(self, item: WorkItem, failed: Request,
                           name: str) -> bool:
        """Failure routing — runs on whichever replica thread terminated
        the request (executor poison-isolation, crash capture, or a
        refused submit).  Updates that replica's health, then reissues a
        fresh clone on the least-loaded healthy survivor, preferring a
        *different* replica when one exists.  Bounded by ``max_retries``
        per work item; the caller commits the FAILED request as the
        item's terminal result on False, so a request can be retried or
        failed but never stranded."""
        i = self._target_index.get(name)
        if i is None:            # disagg attribution stamps engine names
            i = self._engine_index.get(name)
        if i is not None:
            if (isinstance(failed.error, ExecutorCrash)
                    or self.replicas[i].failure is not None):
                self._mark_dead(i)
            else:
                self._mark_degraded(i)
        if isinstance(failed.error, (DeadlineExceeded, ShedError)):
            # the deadline is already blown on any survivor too, and a
            # shed is the fleet's own back-pressure — retrying either
            # would just convert typed rejection into queue pressure
            return False
        tries = getattr(item, "retries", 0)
        if tries >= self.max_retries:
            return False
        item.retries = tries + 1
        # fresh clone from the bare prompt: greedy regeneration on the
        # survivor is bit-identical to an uninterrupted run
        retry = failed.clone()
        order = sorted(self._healthy(),
                       key=lambda j: self.replicas[j].load)
        if self.disaggregated:
            # restart from the bare prompt on a prefill-capable replica
            # when one survives (stable sort: load order kept within each
            # class); a decode-role survivor still works — roles are
            # policy, not capability
            order.sort(key=lambda j: j not in self._prefill_capable)
        for j in order:
            if j == i and len(order) > 1:
                continue
            try:
                self.targets[j].dispatch(item, retry)
            except Exception:  # fault-ok: the candidate refused admission (it may just have died); try the next survivor
                continue
            with self._stats_lock:
                self.stats.retries += 1
            return True
        return False

    # -- placement -------------------------------------------------------------

    def _owner_cap(self, owner: int) -> int:
        if self.affinity_queue_cap is not None:
            return self.affinity_queue_cap
        return 4 * self.replicas[owner].slots

    def _select(self, req: Request) -> int:
        """Replica index for ``req`` (affinity first, then load score).
        The affinity fast path — the common case under shared-prefix
        traffic — snapshots only the owner; the full fleet is snapshotted
        lazily, on fallback to the load score, so dispatch never pays
        R-1 wasted scheduler-lock rounds per hit."""
        healthy = set(self._healthy())
        if self.disaggregated and healthy & self._prefill_capable:
            # fresh prompts go to prefill-capable replicas; decode-role
            # replicas receive work only by migration (or, below, as the
            # last survivors of a fleet-wide failure)
            healthy &= self._prefill_capable
        digests = (prefix_digests(req.prefill_tokens, self.block_size)
                   if self.affinity else [])
        if digests:
            for j in range(len(digests) - 1, -1, -1):   # deepest match wins
                owner = self._prefix_owner.get(digests[j])
                if owner is None or owner not in healthy:
                    continue     # dead owners lost their cache anyway
                snap = self.replicas[owner].load_snapshot()
                # queue depth alone trips the cap: a blocks-starved owner
                # can back up a deep queue while a decode slot sits free
                if snap.queued >= self._owner_cap(owner):
                    with self._stats_lock:
                        self.stats.affinity_fallbacks += 1
                    break               # owner saturated: place by load
                with self._stats_lock:
                    self.stats.affinity_hits += 1
                    self.stats.affinity_blocks += j + 1
                self._register(digests, owner)
                return owner
        # quarantine: only healthy replicas compete for placement.  With
        # the whole fleet dead, any target will refuse the submit and the
        # failure routing turns the request into a typed FAILED terminal
        # (better than blocking dispatch on a replica that cannot return)
        pool = sorted(healthy) or list(range(len(self.replicas)))
        snaps = {i: self.replicas[i].load_snapshot() for i in pool}
        choice = min(pool, key=lambda i: self._score(i, snaps[i], req))
        if digests:
            self._register(digests, choice)
        return choice

    def _score(self, i: int, snap: LoadSnapshot, req: Request):
        """Placement cost (lower wins).  Block-aware: replicas that can
        admit *right now* beat ones that cannot; ties break on queued
        prefill tokens (the work ahead of this request), then free blocks
        (KV headroom), then index (determinism)."""
        if not self.block_aware:         # PR-1 policy: raw request count
            e = self.replicas[i]
            return (snap.queued + (e.slots - snap.free_slots), 0, 0, i)
        e = self.replicas[i]
        need = (e.pool.blocks_for(req.kv_rows + e.spec_rows)
                if e.pool is not None else 0)
        # restorable blocks (idle index-held, spill-then-free on demand)
        # are admission headroom just like strictly free ones — a tiered
        # replica full of idle shared prefixes is not "full"
        avail = ((snap.free_blocks + (snap.restorable_blocks or 0))
                 if snap.free_blocks is not None else None)
        fits_now = (snap.free_slots > 0
                    and (avail is None or avail >= need))
        return (0 if fits_now else 1, snap.queued_tokens,
                -(avail or 0), i)

    def _register(self, digests: list[bytes], owner: int) -> None:
        """Point every full-leading-block digest of a routed prompt at its
        replica.  Re-insertion refreshes recency (dict order is insertion
        order), so the cap drops the coldest prefixes first."""
        for d in digests:
            if d in self._prefix_owner:
                del self._prefix_owner[d]
            self._prefix_owner[d] = owner
        over = len(self._prefix_owner) - self._prefix_cap
        if over > 0:
            # islice touches only the `over` oldest keys — materializing
            # the whole cap-sized dict per routed request would put O(cap)
            # work on the dispatch hot path once the index fills
            for d in list(islice(iter(self._prefix_owner), over)):
                del self._prefix_owner[d]

    # -- dispatch --------------------------------------------------------------

    def _place(self, targets: list[Target], payload: Request) -> Target:
        return targets[self._select(payload)]

    # -- KV migration (disaggregated prefill -> decode handoff) ----------------

    def _select_decode(self, req: Request) -> int:
        """Decode-side admission control: the healthy decode-capable
        replica best placed to adopt ``req`` — same fits-now / queued-
        tokens / free-blocks score as fresh placement, restricted to the
        adopting half of the fleet.  Raises when nobody can adopt (the
        caller fails the request into the bounded retry path)."""
        healthy = set(self._healthy())
        pool = [i for i in self._decode_capable if i in healthy]
        if not pool:
            raise RuntimeError(
                f"request {req.rid}: no healthy decode-capable replica "
                f"left to adopt the migrated KV blocks")
        snaps = {i: self.replicas[i].load_snapshot() for i in pool}
        return min(pool, key=lambda i: self._score(i, snaps[i], req))

    def _migrate(self, src_i: int, req: Request, keys: list, ids: list,
                 gens: list, leaves: list, tokens, last) -> None:
        """Prefill-completion hook (runs on the *source* replica's
        executor thread): pick the adopting replica, record the in-flight
        migration, and submit the self-describing payload to the
        migration channel.  The source's export holds on ``ids`` stay
        live until :meth:`_mig_done` releases them, whatever happens to
        the transfer."""
        src = self.replicas[src_i]
        try:
            dest = self._select_decode(req)
        except Exception as e:  # noqa: BLE001 — nobody can adopt: release
            # the exports and fail the request into the retry path (a
            # mixed survivor may still serve it end-to-end)
            # generation-safe: this free only drops the +1 export pin
            # taken by export_blocks moments ago on this same thread;
            # it cannot recycle blocks another holder still reads
            src.pool.free(ids)
            with self._stats_lock:
                self.stats.migration_failures += 1
            req.error = e
            req.state = RequestState.FAILED
            req.finished_at = time.monotonic()
            if req.on_finish is not None:
                req.on_finish(req)
            return
        tables = list(ids)
        rec = _Migration(req=req, tokens=tokens, last=last, src=src,
                         export_ids=ids, tables=tables, dest=dest)
        with self._mig_lock:
            self._mig_records[id(tables)] = rec
            self._mig_pending += 1
        self._mig_io.submit(("migrate", req.rid, keys, tables, leaves,
                             gens), on_done=self._mig_done)

    def _place_migration(self, targets: list[Target], payload) -> Target:
        with self._mig_lock:
            rec = self._mig_records[id(payload[3])]
        return targets[self._mig_target_index[rec.dest]]

    def _mig_done(self, item: WorkItem) -> None:
        """Migration completion (runs on the migration worker): release
        the source export pins, then either count the success or fail the
        request into the bounded bare-prompt retry path.  Every outcome —
        adopted, dropped by a kv.migrate fault, refused by a dead or full
        receiver — flows through here exactly once, so the export pins
        can never leak and the request can never strand."""
        with self._mig_lock:
            rec = self._mig_records.pop(id(item.payload[3]), None)
            self._mig_pending -= 1
        if rec is None:
            return
        # success or failure, the source's part is over: the receiver
        # owns fresh copies (or nothing arrived).  Cross-thread free is
        # safe — free() never invokes on_demote, and index-held blocks
        # just turn demotable.
        # generation-safe: this free drops only the +1 export pin from
        # export_blocks; the receiver copied the rows into its own pool
        # before complete() fired, so nothing still reads these blocks
        rec.src.pool.free(rec.export_ids)
        result = item.result
        if result is not None and not isinstance(result, WorkError):
            with self._stats_lock:
                self.stats.migrations += 1
            return
        with self._stats_lock:
            self.stats.migration_failures += 1
        req = rec.req
        if isinstance(result, WorkError):
            # adopt_blocks raised (dead/full receiver); req.replica was
            # stamped with the receiver's name, so the failure is charged
            # where it happened
            err = result.error
        else:
            # an injected kv.migrate drop: the payload vanished in flight
            err = FaultError("kv.migrate",
                             f"migration of request {req.rid} dropped "
                             f"in flight")
        req.error = err
        req.state = RequestState.FAILED
        req.finished_at = time.monotonic()
        if req.on_finish is not None:
            req.on_finish(req)     # -> _on_request_failed -> retry clone

    def drain_migrations(self, timeout: float = 5.0) -> None:
        """Wait until no migration is in flight.  Export pins release in
        the completion hook, which can lag the *request's* completion by
        a worker beat — leak sweeps (and teardown) must not race it."""
        if self._mig_io is None:
            return
        deadline = time.monotonic() + timeout
        while True:
            with self._mig_lock:
                n = self._mig_pending
            if n == 0:
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{n} migration(s) still in flight after {timeout}s")
            time.sleep(0.0005)

    # -- work stealing ---------------------------------------------------------

    @staticmethod
    def _thief_can_take(thief: ServingEngine, snap: LoadSnapshot):
        """Admission filter in the *thief's* geometry (its max_len, block
        size, and free blocks — the donor pool's block math would be
        wrong on a heterogeneous fleet): only steal what the thief could
        admit right now, or the request ping-pongs between queues
        instead of ever decoding."""
        def ok(req: Request) -> bool:
            if req.kv_rows > thief.max_len:      # per-slot KV capacity
                return False
            if thief.pool is not None:
                # the thief's own speculative overhang rides on top of the
                # request's worst case, exactly as its admission will charge
                need = thief.pool.blocks_for(req.kv_rows
                                             + thief.spec_rows)
                avail = snap.free_blocks + (snap.restorable_blocks or 0)
                if need > min(avail, thief.pool.capacity):
                    return False
            return True
        return ok

    def _rebalance_once(self) -> int:
        """One stealing pass: every idle replica takes the lowest-ranked
        queued request it could admit right now from the most backlogged
        peer (by queued prefill tokens).  Returns requests moved."""
        moved = 0
        healthy = self._healthy()
        snaps = {i: self.replicas[i].load_snapshot() for i in healthy}
        for i in healthy:
            snap = snaps[i]
            if not snap.idle:
                continue
            if self.disaggregated and self.replicas[i].role == "decode":
                # queued work is fresh prompts, and a decode-role replica
                # stealing one would prefill it locally — the recompute
                # disaggregation exists to avoid.  Its work arrives as
                # migrated blocks instead.
                continue
            donors = sorted(
                (j for j in healthy if j != i and snaps[j].queued > 0
                 and not (self.disaggregated
                          and self.replicas[j].role == "decode")),
                # a decode-role replica's queue holds *adopted* requests
                # whose KV blocks already landed in its pool — stealing
                # one would strand the staged payload and re-prefill a
                # prompt that is already computed
                key=lambda j: (snaps[j].queued_tokens, snaps[j].queued),
                reverse=True)
            thief = self.replicas[i]
            for j in donors:
                got = self.replicas[j].scheduler.steal(
                    max_items=1,
                    can_take=self._thief_can_take(thief, snap))
                took = 0
                for req in got:
                    try:
                        # on_finish (WorkItem.complete) and submitted_at
                        # ride along: TTFT spans the migration, and a
                        # steal racing a reissue resolves first-wins
                        thief.submit(req)
                        took += 1
                    except Exception:  # noqa: BLE001 — thief refused
                        # (CapacityError is defensive only: can_take
                        # pre-filters; anything else means the thief died
                        # between snapshot and submit).  The stolen
                        # request must not vanish: hand it back to its
                        # donor, else fail it into the retry path (its
                        # on_finish routes the failure to a survivor).
                        try:
                            self.replicas[j].submit(req)
                        except Exception as e2:  # noqa: BLE001 — donor
                            # also gone mid-steal
                            req.state = RequestState.FAILED
                            req.error = e2
                            if req.on_finish is not None:
                                req.on_finish(req)
                moved += took
                if took:                # thief's free slot is now spoken for
                    break
        with self._stats_lock:
            self.stats.steals += moved
        return moved

    def _steal_loop(self) -> None:
        while not self._steal_stop.wait(self.steal_interval_s):
            try:
                self._heartbeat()
                self._rebalance_once()
            except Exception as e:  # noqa: BLE001 — one bad tick must not
                # silently kill rebalancing for the rest of the serve;
                # count it and stash the exception for serve() to
                # re-surface after results are copied back
                with self._stats_lock:
                    self.stats.rebalance_errors += 1
                    self._rebalance_exc = e

    def _start_stealing(self) -> None:
        if not self.steal or self._steal_thread is not None:
            return
        self._steal_stop.clear()
        self._steal_thread = threading.Thread(target=self._steal_loop,
                                              name="router-rebalance",
                                              daemon=True)
        self._steal_thread.start()

    def _stop_stealing(self) -> None:
        if self._steal_thread is None:     # idempotent: double stop is a
            return                         # no-op, never an error
        self._steal_stop.set()
        self._steal_thread.join(timeout=10.0)
        if self._steal_thread.is_alive():
            raise RuntimeError("rebalance thread did not stop within 10s")
        self._steal_thread = None

    def stop(self) -> None:
        """Idempotent fleet teardown for service-mode use outside
        :meth:`serve` (which tears down its own context): stop the
        rebalance thread and every replica executor.  Captured executor
        crashes are suppressed (`raise_failure=False` — they were already
        routed through retry); every replica is offered a stop before the
        first teardown error re-surfaces."""
        errors: list[BaseException] = []
        try:
            self._stop_stealing()
        except Exception as e:  # noqa: BLE001 — aggregated below; the
            # replicas must still be stopped
            errors.append(e)
        try:
            # settle in-flight migrations while their receivers still run
            # (an adopt against a stopped executor would strand a request)
            self.drain_migrations()
        except Exception as e:  # noqa: BLE001 — aggregated below
            errors.append(e)
        for replica in self.replicas:
            try:
                replica.stop(raise_failure=False)
            except Exception as e:  # noqa: BLE001 — aggregated below
                errors.append(e)
        if errors:
            raise errors[0]

    # -- serving ---------------------------------------------------------------

    def serve(self, requests: list[Request], *,
              window: int | None = None) -> ServeStats:
        """Routed dispatch of *individual* requests with out-of-order
        collection and (optionally) live work stealing; blocks until every
        request is DONE."""
        window = window or 2 * sum(e.slots for e in self.replicas)
        base = [e.begin_window() for e in self.replicas]
        with self._stats_lock:
            rbase = RouterStats(**vars(self.stats))
        t0 = time.monotonic()
        for r in requests:
            # arrival = hand-off to the router; clones inherit it, so both
            # reissue and stealing keep TTFT measured from here
            if r.submitted_at is None:
                r.submitted_at = t0
        self._start_stealing()
        try:
            with OffloadEngine(self.targets, scheduler=self._place,
                               deadline_s=self.deadline_s) as eng:
                results, _ = eng.run_unordered(requests, window=window)
        finally:
            self._stop_stealing()
        # every request resolved implies every migration resolved, but the
        # completion hook's export release can lag by a worker beat — and
        # the caller's leak sweep must see the pins gone
        self.drain_migrations()
        stats = ServeStats(requests=len(requests),
                           wall_s=time.monotonic() - t0)
        delivered = 0
        for seq, done in results:      # copy the winning clone's results back
            orig = requests[seq]
            if isinstance(done, WorkError):
                # the replica worker itself raised (not a routed request
                # failure): surface it as a typed FAILED terminal
                orig.state = RequestState.FAILED
                orig.error = done.error
                orig.finished_at = time.monotonic()
                continue
            orig.output = done.output
            orig.state = done.state
            orig.error = done.error
            orig.first_token_at = done.first_token_at
            orig.finished_at = done.finished_at
            delivered += len(done.output)
        # declarative fleet aggregation: every ServeStats field merges by
        # its MERGE_RULES entry, so new fields cannot silently drop here
        for e, b in zip(self.replicas, base):
            stats.merge_from(e.collect_window(b, [], 0.0))
        # replica windows count every decoded token, including the losing
        # copy of a reissue/steal race; the fleet number is *delivered*
        # tokens (winning clones only), so throughput never double-counts
        stats.tokens = delivered
        with self._stats_lock:
            stats.router_steals = self.stats.steals - rbase.steals
            stats.router_affinity_hits = (self.stats.affinity_hits
                                          - rbase.affinity_hits)
            stats.requests_retried = self.stats.retries - rbase.retries
            stats.replica_failures = (self.stats.replica_failures
                                      - rbase.replica_failures)
            rebalance_exc = self._rebalance_exc
            self._rebalance_exc = None
        # the merged per-replica count tallies every failure event,
        # including ones a retry later recovered; the fleet-level number
        # is *terminal* failures — requests whose callers got no answer
        stats.requests_failed = sum(
            1 for r in requests if r.state is RequestState.FAILED)
        # derived ratios (kv_pool_util, accept_rate) were recomputed by
        # merge_from itself from the merged peaks/capacities/counters —
        # no caller-side fixup to forget here
        stats.fill_request_metrics(requests)
        if rebalance_exc is not None:
            # hardening contract: a rebalance tick that raised was
            # contained mid-serve (counted in rebalance_errors) but must
            # not stay silent — results are already copied back onto the
            # caller's requests, so re-surface it here
            raise rebalance_exc
        return stats


class MultiReplicaEngine(ReplicaRouter):
    """The PR-1 dispatcher, kept as the routing A/B baseline and for
    back-compat: request-count least-loaded placement, no prefix
    affinity, no work stealing.  New code should construct
    :class:`ReplicaRouter` directly."""

    def __init__(self, replicas: list[ServingEngine], *,
                 deadline_s: float | None = None,
                 max_retries: int = 2):
        super().__init__(replicas, affinity=False, steal=False,
                         block_aware=False, deadline_s=deadline_s,
                         max_retries=max_retries)
