"""Continuous-batching scheduler: admission queue + fixed decode slots.

The paper keeps every NCS stick saturated by split-phase load/collect; the
LM-serving analogue is keeping every *decode slot* saturated.  This module
owns the request lifecycle

    QUEUED -> PREFILL -> DECODE -> DONE

and the slot bookkeeping: a fixed number of decode slots per replica, an
admission deque feeding them, and thread-safe submit so a replica pull-loop
(or a live traffic source) can admit requests mid-stream.  The moment a
slot's request finishes, the next queued request is admitted into that slot
— no lock-step waves, no length bucketing.

With a :class:`~repro.serving.kv_pool.KVBlockPool` attached, admission is
*block-aware*: a request enters a slot only when the pool can reserve its
worst-case block count (prompt + decode budget), and release returns its
blocks — so admission is bounded by live KV rows, not by worst-case
``max_len`` per slot.

The scheduler is pure bookkeeping: the :class:`~repro.serving.engine.
ServingEngine` executor owns params, KV state, and the jitted decode step.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

import numpy as np

from repro.serving.kv_pool import KVBlockPool
from repro.serving.sampler import Sampler, greedy


class RequestState(Enum):
    QUEUED = "queued"      # in the admission queue
    PREFILL = "prefill"    # assigned a slot; prompt being prefilled
    DECODE = "decode"      # occupying a decode slot
    DONE = "done"          # all tokens emitted


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    sampler: Sampler = field(default_factory=greedy)
    # filled by the scheduler/engine:
    state: RequestState = RequestState.QUEUED
    output: list = field(default_factory=list)
    submitted_at: float = field(default_factory=time.monotonic)
    first_token_at: float | None = None
    finished_at: float | None = None
    on_finish: Callable[["Request"], None] | None = None
    # paged-KV bookkeeping (engine/scheduler-owned; empty when contiguous)
    block_ids: list = field(default_factory=list)
    blocks_reserved: int = 0

    @property
    def kv_rows(self) -> int:
        """Worst-case KV rows written: every position except the final
        sampled token (which is never fed back)."""
        return len(self.prompt) + self.max_new_tokens - 1

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def tpot_s(self) -> float | None:
        """Time per output token after the first (decode cadence)."""
        if self.finished_at is None or self.first_token_at is None \
                or len(self.output) < 2:
            return None
        return ((self.finished_at - self.first_token_at)
                / (len(self.output) - 1))

    def clone(self) -> "Request":
        """Fresh-output copy for straggler reissue across replicas: two
        replicas may decode the same request concurrently; each works on
        its own clone and the first completion wins."""
        return Request(rid=self.rid, prompt=self.prompt,
                       max_new_tokens=self.max_new_tokens,
                       sampler=self.sampler, submitted_at=self.submitted_at)


class ContinuousScheduler:
    """Admission queue feeding a fixed set of decode slots.

    Thread-safe: `submit` may be called from any thread (a live traffic
    source, a replica pull-loop) while the executor thread runs
    `admit`/`active`/`release`.
    """

    def __init__(self, num_slots: int, pool: KVBlockPool | None = None):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.pool = pool
        self.slots: list[Request | None] = [None] * num_slots
        self._queue: deque[Request] = deque()
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)

    # -- producer side ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.pool is not None:
            self.pool.validate_rows(req.kv_rows, req.rid)
        with self._work:
            req.state = RequestState.QUEUED
            self._queue.append(req)
            self._work.notify_all()

    # -- executor side ---------------------------------------------------------

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the admission queue; the returned
        (slot, request) pairs are in PREFILL state and need their prompt
        prefilled into the batched KV state.

        Block-aware mode: a request is admitted only when the pool can
        reserve its worst-case block count; FIFO order is preserved, so a
        too-large head-of-queue request waits for blocks to free rather
        than being overtaken."""
        out: list[tuple[int, Request]] = []
        with self._lock:
            for i in range(self.num_slots):
                if self.slots[i] is None and self._queue:
                    req = self._queue[0]
                    if self.pool is not None:
                        need = self.pool.blocks_for(req.kv_rows)
                        if not self.pool.reserve(need):
                            break               # wait for blocks to free
                        req.blocks_reserved = need
                    self._queue.popleft()
                    req.state = RequestState.PREFILL
                    self.slots[i] = req
                    out.append((i, req))
        return out

    def active(self) -> list[tuple[int, Request]]:
        with self._lock:
            return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def release(self, slot: int) -> Request:
        """Free a slot whose request finished (state already DONE); returns
        the request's KV blocks (and any unallocated reservation tail) to
        the pool."""
        with self._lock:
            req = self.slots[slot]
            assert req is not None, f"release of empty slot {slot}"
            self.slots[slot] = None
        if self.pool is not None:
            if req.block_ids:
                self.pool.free(req.block_ids)
            if req.blocks_reserved > len(req.block_ids):
                self.pool.unreserve(req.blocks_reserved - len(req.block_ids))
            req.block_ids = []
            req.blocks_reserved = 0
        return req

    # -- introspection ---------------------------------------------------------

    @property
    def queued(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def occupied(self) -> int:
        with self._lock:
            return sum(r is not None for r in self.slots)

    @property
    def load(self) -> int:
        """Queue depth analogue for least-loaded dispatch across replicas."""
        with self._lock:
            return len(self._queue) + sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        with self._lock:
            return bool(self._queue) or any(r is not None for r in self.slots)

    def wait_for_work(self, timeout: float | None = None) -> bool:
        with self._work:
            if self.has_work():
                return True
            self._work.wait(timeout)
            return self.has_work()
