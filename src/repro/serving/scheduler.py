r"""Continuous-batching scheduler: SLO-aware admission + fixed decode slots.

The paper keeps every NCS stick saturated by split-phase load/collect; the
LM-serving analogue is keeping every *decode slot* saturated.  This module
owns the request lifecycle

    QUEUED -> PREFILL -> DECODE -> DONE
                ^___________|   \___ FAILED   (poison fault, deadline,
                (preemption re-queues         or retries exhausted)
                 a decode)

and the slot bookkeeping: a fixed number of decode slots per replica, an
admission queue feeding them, and thread-safe submit so a replica pull-loop
(or a live traffic source) can admit requests mid-stream.  The moment a
slot's request finishes, the next queued request is admitted into that slot
— no lock-step waves, no length bucketing.  With the engine's chunked
prefill a request may stay in PREFILL across several executor steps
(its prompt prefills one chunk at a time between decode steps); only
:meth:`ContinuousScheduler.decoding` slots join the batched decode.

Admission is a **priority queue**, not FIFO: requests are ordered by
``priority`` (higher serves first), then by TTFT-SLO deadline
(``submitted_at + slo_ttft_s``; requests without an SLO sort last within
their priority), then by arrival.  ``submit`` stamps ``submitted_at`` at
actual submission (unless the caller already set it — the multi-replica
reissue path pins arrival time on the original so clones inherit it), so
TTFT always measures queueing + prefill, never pre-construction time.

With a :class:`~repro.serving.kv_pool.KVBlockPool` attached, admission is
*block-aware*: a request enters a slot only when the pool can reserve its
worst-case block count (prompt + decode budget), and release returns its
blocks — so admission is bounded by live KV rows, not by worst-case
``max_len`` per slot.  When the head of the queue outranks an active
decode and the pool cannot satisfy it, the scheduler **preempts**: the
lowest-priority (then most-blocks-remaining) active decode is evicted
recompute-style — its blocks return to the pool, its generated tokens fold
into its prompt (see :attr:`Request.prefill_tokens`), and it re-enters the
queue to be re-prefilled when space frees.  The executor learns about
evictions via :meth:`ContinuousScheduler.drain_preempted` so it can retire
the victim's block table before the freed blocks are reused.

Across replicas, the scheduler is the work-stealing substrate: an idle
peer pulls still-QUEUED requests off the back of this queue via
:meth:`ContinuousScheduler.steal` (heap invariants and ``submitted_at``
preserved), and :meth:`ContinuousScheduler.load_snapshot` exposes the
block-aware load triple the :class:`~repro.serving.router.ReplicaRouter`
places on — free slots, free KV blocks, queued prefill tokens — instead
of the raw request count.

The scheduler is pure bookkeeping: the :class:`~repro.serving.engine.
ServingEngine` executor owns params, KV state, and the jitted decode step.
"""
from __future__ import annotations

import heapq
import math
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, NamedTuple

import numpy as np

from repro.serving.faults import ExecutorCrash
from repro.serving.kv_pool import KVBlockPool
from repro.serving.sampler import Sampler, greedy


class RequestState(Enum):
    QUEUED = "queued"      # in the admission queue
    PREFILL = "prefill"    # assigned a slot; prompt being prefilled
    PREFILLED = "prefilled"  # prefill done on a prefill-role replica;
    #                          KV blocks migrating to a decode replica
    #                          (terminal *on the source* — the request
    #                          re-enters QUEUED on the receiver)
    DECODE = "decode"      # occupying a decode slot
    DONE = "done"          # all tokens emitted
    FAILED = "failed"      # terminal: poison fault / deadline / shed /
    #                        retries exhausted — req.error says which


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    sampler: Sampler = field(default_factory=greedy)
    priority: int = 0               # higher serves first; preempts lower
    slo_ttft_s: float | None = None  # TTFT target; orders within a priority
    deadline_s: float | None = None  # hard wall from submit; elapsed -> FAILED
    # filled by the scheduler/engine:
    state: RequestState = RequestState.QUEUED
    output: list = field(default_factory=list)
    submitted_at: float | None = None    # stamped by scheduler.submit()
    first_token_at: float | None = None
    finished_at: float | None = None
    on_finish: Callable[["Request"], None] | None = None
    preempted_count: int = 0        # times evicted from a decode slot
    error: BaseException | None = None   # set iff state is FAILED
    # engine that currently owns the request — stamped at submit and
    # re-stamped by adopt_blocks when a migration hands it to a decode
    # replica, so failure attribution follows the request, not the
    # dispatch target
    replica: str | None = None
    # paged-KV bookkeeping (engine/scheduler-owned; empty when contiguous).
    # block_ids[:shared_blocks] are prefix-shared (refcounted, read-only);
    # blocks_reserved is the *remaining* unallocated reservation tail.
    block_ids: list = field(default_factory=list)
    blocks_reserved: int = 0
    shared_blocks: int = 0
    # eviction leaves the freed ids here (block_ids is cleared) so the
    # engine can spill the victim's still-intact rows to the host tier
    # before any new prefill overwrites them; the engine consumes and
    # clears it in its drain_preempted handler
    evicted_block_ids: list = field(default_factory=list)
    arrival_seq: int | None = None  # per-scheduler heap tiebreak (private)

    @property
    def kv_rows(self) -> int:
        """Worst-case KV rows written: every position except the final
        sampled token (which is never fed back)."""
        return len(self.prompt) + self.max_new_tokens - 1

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What a (re-)prefill must process: the prompt, plus — after a
        preemption — the tokens already generated, folded in so the request
        resumes recompute-style from where it was evicted."""
        if not self.output:
            return self.prompt
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.output, np.int32)])

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def slo_miss(self) -> bool | None:
        """True/False once the first token is out; None without an SLO."""
        if self.slo_ttft_s is None or self.ttft_s is None:
            return None
        return self.ttft_s > self.slo_ttft_s

    @property
    def tpot_s(self) -> float | None:
        """Time per output token after the first (decode cadence)."""
        if self.finished_at is None or self.first_token_at is None \
                or len(self.output) < 2:
            return None
        return ((self.finished_at - self.first_token_at)
                / (len(self.output) - 1))

    def clone(self) -> "Request":
        """Fresh-output copy for straggler reissue across replicas: two
        replicas may decode the same request concurrently; each works on
        its own clone and the first completion wins."""
        return Request(rid=self.rid, prompt=self.prompt,
                       max_new_tokens=self.max_new_tokens,
                       sampler=self.sampler, priority=self.priority,
                       slo_ttft_s=self.slo_ttft_s,
                       deadline_s=self.deadline_s,
                       submitted_at=self.submitted_at)

    def deadline_elapsed(self, now: float) -> bool:
        """True once the per-request hard deadline has passed (always
        False without one or before submission)."""
        return (self.deadline_s is not None
                and self.submitted_at is not None
                and now - self.submitted_at > self.deadline_s)


class LoadSnapshot(NamedTuple):
    """One replica's load at a glance, for cross-replica placement.

    Raw request count (the PR-1 dispatch metric) hides the resource that
    actually gates admission: a replica with two queued requests and zero
    free KV blocks is *worse* than one with four queued requests and half
    its pool free.  The router scores replicas on this snapshot instead.
    """
    free_slots: int
    free_blocks: int | None     # None for contiguous (pool-less) engines
    queued: int                 # requests in the admission queue
    queued_tokens: int          # prompt(+resume) tokens awaiting prefill
    # hot vs restorable: free_blocks is immediately-free device headroom;
    # restorable_blocks counts index-held blocks the pool can demote to
    # the host tier on demand — admission capacity is their sum, but a
    # replica serving out of restorable headroom pays spill traffic, so
    # the router sees both rather than one blurred number
    restorable_blocks: int | None = None

    @property
    def idle(self) -> bool:
        """Nothing queued and at least one slot open — the work-stealing
        trigger (block headroom is checked separately against the
        candidate's actual need)."""
        return self.queued == 0 and self.free_slots > 0


class ContinuousScheduler:
    """Priority admission queue feeding a fixed set of decode slots.

    Thread-safe: `submit` may be called from any thread (a live traffic
    source, a replica pull-loop) while the executor thread runs
    `admit`/`active`/`release`.

    ``preemption=False`` disables eviction (the FIFO-era behaviour under
    block pressure: the head of the queue waits for blocks to free).
    """

    def __init__(self, num_slots: int, pool: KVBlockPool | None = None, *,
                 preemption: bool = True, spec_rows: int = 0):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.pool = pool
        self.preemption = preemption
        # speculative decoding: each slot may hold up to ``spec_rows``
        # provisional candidate KV rows past its committed length during a
        # verify pass, so worst-case reservations must budget for them —
        # otherwise a verify-time grow could exceed the admission promise
        self.spec_rows = spec_rows
        self.slots: list[Request | None] = \
            [None] * num_slots               # guarded-by: self._lock
        # heap of (-priority, slo deadline, arrival seq, request); the seq
        # is unique per scheduler so requests themselves are never compared
        self._heap: list[tuple[float, float, int, Request]] = \
            []                               # guarded-by: self._lock
        self._seq = 0                        # guarded-by: self._lock
        self._preempted: list[tuple[int, Request]] = \
            []                               # guarded-by: self._lock
        self._preemptions = 0                # guarded-by: self._lock
        # blocked-head admission cache: (head arrival_seq, capacity
        # version) of the last admit() that found the queue head unfit.
        # While the version is unchanged, re-running the slot scan /
        # reserve / preemption probe is provably the same answer, so
        # admit() returns immediately — the executor no longer re-prices
        # a blocked head every step of a long decode.
        self._blocked_sig: tuple | None = None  # guarded-by: self._lock
        self._event_epoch = 0                # guarded-by: self._lock
        self._head_checks_skipped = 0        # guarded-by: self._lock
        # executor crash capture: once set, submit() raises instead of
        # queueing into a scheduler nothing will ever drain again
        self._poisoned: BaseException | None = None  # guarded-by: self._lock
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)  # alias-of: self._lock

    # -- lifetime counters (monotonic; locked so a router/bench thread can
    # -- read them mid-flight without tearing against the executor) -----------

    @property
    def preemptions(self) -> int:
        with self._lock:
            return self._preemptions

    @property
    def head_checks_skipped(self) -> int:
        with self._lock:
            return self._head_checks_skipped

    # -- producer side ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.pool is not None:
            self.pool.validate_rows(req.kv_rows + self.spec_rows, req.rid)
        with self._work:
            if self._poisoned is not None:
                raise ExecutorCrash(
                    "executor is dead; submit refused"
                ) from self._poisoned
            if req.submitted_at is None:     # stamp at submission, not at
                req.submitted_at = time.monotonic()  # Request construction
            req.state = RequestState.QUEUED
            self._push(req)
            self._event_epoch += 1           # a new head may outrank
            self._work.notify_all()

    def poison(self, exc: BaseException) -> None:
        """Executor crash capture: refuse every later submit() with
        :class:`ExecutorCrash` chained to the original failure, closing
        the race between a crashing executor and a concurrent producer
        (whose request would otherwise queue forever)."""
        with self._work:
            self._poisoned = exc
            self._work.notify_all()

    # assumes-lock: self._lock
    def _push(self, req: Request) -> None:
        """Queue ``req`` at (priority, SLO deadline, arrival) order.  A
        re-queued preemption victim keeps its original arrival seq, so it
        resumes ahead of later arrivals of the same priority."""
        if req.arrival_seq is None:
            req.arrival_seq = self._seq
            self._seq += 1
        deadline = (req.submitted_at + req.slo_ttft_s
                    if req.slo_ttft_s is not None else math.inf)
        heapq.heappush(self._heap,
                       (-req.priority, deadline, req.arrival_seq, req))

    # -- executor side ---------------------------------------------------------

    # assumes-lock: self._lock
    def _capacity_version(self) -> tuple[int, int]:
        """Changes iff admission capacity may have grown since last read:
        scheduler events (submit / release / steal / notify_capacity) and
        pool headroom growth (free / unreserve / newly demotable).
        Capacity-*shrinking* events (reserve, alloc) are deliberately
        excluded — a cached "head does not fit" stays correct through
        them."""
        return (self._event_epoch,
                self.pool.avail_epoch if self.pool is not None else 0)

    def notify_capacity(self) -> None:
        """Executor hint that admission prospects changed outside the
        scheduler's own bookkeeping — e.g. a PREFILL request turned
        DECODE and is now preemption-eligible.  Invalidates the
        blocked-head cache."""
        with self._lock:
            self._event_epoch += 1

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the admission queue; the returned
        (slot, request) pairs are in PREFILL state and need their prompt
        prefilled into the batched KV state.

        Block-aware (paged) mode: a request is admitted only when a slot
        is free and the pool can reserve its worst-case block count.
        Queue order is strict — a blocked head-of-queue request is never
        overtaken; it either preempts lower-priority active decodes (see
        :meth:`_preempt_for` — slot pressure and block pressure both
        qualify) or waits for capacity to free.  Preemption needs the
        pool's recompute bookkeeping, so contiguous (pool=None) engines
        always wait for a natural slot release."""
        out: list[tuple[int, Request]] = []
        with self._lock:
            while self._heap:
                req = self._heap[0][3]
                if self._blocked_sig is not None and self._blocked_sig == \
                        (req.arrival_seq, self._capacity_version()):
                    # same head, no capacity-growing event since it last
                    # failed: the full check would fail identically
                    self._head_checks_skipped += 1
                    break
                slot = next((i for i, r in enumerate(self.slots)
                             if r is None), None)
                need = (self.pool.blocks_for(req.kv_rows + self.spec_rows)
                        if self.pool is not None else 0)
                # NB: reserve only once a slot exists, so a blocked head
                # never strands a reservation it cannot use yet
                ok = slot is not None and (self.pool is None
                                           or self.pool.reserve(need))
                if not ok:
                    # head blocked on a slot or on blocks: a higher-
                    # priority head may evict lower-priority decodes
                    if not (self.preemption and self.pool is not None
                            and self._preempt_for(req, need)):
                        # wait for capacity to free; cache the verdict
                        # against the current capacity version
                        self._blocked_sig = (req.arrival_seq,
                                             self._capacity_version())
                        break
                    slot = next((i for i, r in enumerate(self.slots)
                                 if r is None), None)
                    if slot is None or not self.pool.reserve(need):
                        self._blocked_sig = (req.arrival_seq,
                                             self._capacity_version())
                        break               # defensive; _preempt_for holds
                if self.pool is not None:
                    req.blocks_reserved = need
                heapq.heappop(self._heap)
                req.state = RequestState.PREFILL
                self.slots[slot] = req
                out.append((slot, req))
                self._blocked_sig = None     # progress: cache is moot
        return out

    # assumes-lock: self._lock
    def _preempt_for(self, req: Request, need: int) -> bool:
        """Evict lower-priority active decodes until ``req`` has a slot
        and ``need`` blocks could be reserved.  Victim order: lowest
        priority first, then most blocks remaining (evicting the
        longest-tail decode frees the most future demand).  Returns False
        — touching nothing — when even evicting every eligible victim
        could not free enough, so a doomed admission never wastes
        completed decode work.  At least one victim is always evicted on
        success (the caller may need the slot, not just the blocks).
        Called under the scheduler lock."""
        victims = sorted(
            ((i, r) for i, r in enumerate(self.slots)
             if r is not None and r.state is RequestState.DECODE
             and r.priority < req.priority),
            key=lambda ir: (ir[1].priority, -ir[1].blocks_reserved,
                            -len(ir[1].block_ids)))
        if not victims:
            return False
        # gain: a victim's block comes back to the preemptor if no other
        # *request* shares it — either straight to the free list
        # (refcount 1) or as a demotable index-held block (refcount 2
        # with the prefix index's hold; reserve() demotes it on demand).
        # The reservation tail always returns.  Conservative when two
        # victims share a block (counted for neither) — declining is
        # always safe, evicting-for-nothing is not.
        gain = sum(self.pool.reclaimable_count(r.block_ids)
                   + r.blocks_reserved for _, r in victims)
        if self.pool.available_blocks + gain < need:
            return False
        for slot, victim in victims:
            self._evict(slot, victim)
            if self.pool.available_blocks >= need:
                return True
        return self.pool.available_blocks >= need

    # assumes-lock: self._lock
    def _evict(self, slot: int, victim: Request) -> None:
        """Recompute-style preemption of one active decode: free its
        blocks, fold its generated tokens into its prompt (via
        ``prefill_tokens`` at re-admission), and re-queue it.  The executor
        must retire the victim's block table before reusing the freed
        blocks — it learns the slot via :meth:`drain_preempted`."""
        self.slots[slot] = None
        if victim.block_ids:
            # Leave the freed ids on the victim so a tiered engine can
            # spill their contents to the host tier before the pool
            # re-scatters them (the engine consumes and clears this list
            # in its drain_preempted handler, which runs before any
            # post-eviction allocation touches the device state).
            victim.evicted_block_ids = list(victim.block_ids)
            self.pool.free(victim.block_ids)
        if victim.blocks_reserved:
            self.pool.unreserve(victim.blocks_reserved)
        victim.block_ids = []
        victim.blocks_reserved = 0
        victim.shared_blocks = 0
        victim.preempted_count += 1
        victim.state = RequestState.QUEUED
        self._preemptions += 1
        self._preempted.append((slot, victim))
        self._push(victim)

    def drain_preempted(self) -> list[tuple[int, Request]]:
        """(slot, victim) pairs evicted since the last call — the executor
        retires each slot's block table before the freed blocks can be
        re-scattered."""
        with self._lock:
            out, self._preempted = self._preempted, []
        return out

    def drain_queue(self) -> list[Request]:
        """Remove and return every still-QUEUED request — the executor's
        crash path and the router's quarantine path use this to reclaim
        work a dead replica will never serve.  Active slots are *not*
        touched (their pool state needs the engine's retirement path)."""
        with self._lock:
            out = [e[3] for e in self._heap]
            self._heap = []
            self._blocked_sig = None
            self._event_epoch += 1
        return out

    def expire_deadlines(self, now: float) -> list[Request]:
        """Remove and return queued requests whose hard ``deadline_s``
        has already elapsed — decoding them would deliver tokens the
        caller has given up on.  Active slots are checked by the
        executor (which owns their pool state)."""
        with self._lock:
            expired = [e[3] for e in self._heap
                       if e[3].deadline_elapsed(now)]
            if expired:
                dead = set(map(id, expired))
                self._heap = [e for e in self._heap
                              if id(e[3]) not in dead]
                heapq.heapify(self._heap)
                self._blocked_sig = None
                self._event_epoch += 1
        return expired

    def active(self) -> list[tuple[int, Request]]:
        with self._lock:
            return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def decoding(self) -> list[tuple[int, Request]]:
        """Slots whose request is past prefill — the only ones the batched
        decode step samples and advances.  With chunked prefill a request
        can sit in PREFILL across many executor steps while decode steps
        run around it, so ``active`` (slot occupancy) and ``decoding``
        (decode participation) are no longer the same set."""
        with self._lock:
            return [(i, r) for i, r in enumerate(self.slots)
                    if r is not None and r.state is RequestState.DECODE]

    def release(self, slot: int) -> Request:
        """Free a slot whose request finished (state already DONE); drops
        the request's hold on its KV blocks (shared blocks survive while
        other requests still hold them) and returns the unallocated
        reservation tail to the pool."""
        with self._lock:
            req = self.slots[slot]
            assert req is not None, f"release of empty slot {slot}"
            self.slots[slot] = None
            self._event_epoch += 1  # a slot opened: blocked head may now fit
        if self.pool is not None:
            if req.block_ids:
                # generation-safe: every release caller immediately
                # _retire_slot()s the slot (trash-table redirect) before
                # the next scatter, and the engine's prefix index checks
                # block_live() before seeding from any (id, gen) entry
                self.pool.free(req.block_ids)
            if req.blocks_reserved:
                self.pool.unreserve(req.blocks_reserved)
            req.block_ids = []
            req.blocks_reserved = 0
            req.shared_blocks = 0
        return req

    # -- cross-replica work stealing -------------------------------------------

    def steal(self, max_items: int = 1, *,
              can_take: Callable[[Request], bool] | None = None
              ) -> list[Request]:
        """Remove up to ``max_items`` still-QUEUED requests so an idle peer
        scheduler can take them over (cross-replica work stealing).

        Victims come from the *back* of the queue — the lowest-ranked
        entries by (priority, SLO deadline, arrival), i.e. the requests
        this replica would serve last — so the local heap's service order
        for everything that stays is untouched.  While other entries are
        queued, the head (the request this replica serves next, typically
        with its prefix blocks already resident) is never stolen — a
        ``can_take``-filtered scan cannot walk forward into it past
        rejected candidates.  A *sole* queued request is fair game: the
        donor has no capacity for it now (else it would be admitted), so
        migrating it to an idle peer strictly helps its TTFT.  The
        surviving heap is re-heapified, preserving its invariants.

        Stolen requests keep their ``submitted_at`` stamp (TTFT spans the
        migration: re-submission on the thief preserves a pre-stamped
        arrival) plus priority and SLO; only the per-scheduler
        ``arrival_seq`` is cleared, so the thief's heap assigns its own
        tiebreak and never compares seqs minted by two schedulers.

        ``can_take`` filters candidates by the *thief's* admission
        capacity (its ``max_len``, block size, and free blocks — this
        scheduler's own pool geometry says nothing about the thief's):
        a request the thief could not admit must stay here, or it would
        ping-pong between queues instead of ever decoding.
        """
        stolen: list[Request] = []
        with self._lock:
            take: set[int] = set()
            # back of the queue first: largest heap key = served last;
            # the final (smallest-key) index is the head — sliced off
            # (when it has company) so a filtered scan can never walk
            # forward into it
            order = sorted(range(len(self._heap)),
                           key=lambda i: self._heap[i][:3], reverse=True)
            if len(order) > 1:
                order = order[:-1]
            for i in order:
                if len(stolen) >= max_items:
                    break
                req = self._heap[i][3]
                if can_take is not None and not can_take(req):
                    continue
                take.add(i)
                stolen.append(req)
            if take:
                self._heap = [e for i, e in enumerate(self._heap)
                              if i not in take]
                heapq.heapify(self._heap)
                for req in stolen:
                    req.arrival_seq = None
                self._event_epoch += 1  # queue shrank: head identity/rank moved
        return stolen

    # -- introspection ---------------------------------------------------------

    def load_snapshot(self) -> LoadSnapshot:
        """Block-aware load for cross-replica placement (racy by design:
        the executor keeps running; the router treats it as a hint)."""
        with self._lock:
            free_slots = sum(r is None for r in self.slots)
            queued = len(self._heap)
            queued_tokens = sum(len(e[3].prompt) + len(e[3].output)
                                for e in self._heap)
        free_blocks = (self.pool.free_blocks if self.pool is not None
                       else None)
        restorable = (self.pool.demotable_count if self.pool is not None
                      else None)
        return LoadSnapshot(free_slots=free_slots, free_blocks=free_blocks,
                            queued=queued, queued_tokens=queued_tokens,
                            restorable_blocks=restorable)

    @property
    def queued(self) -> int:
        with self._lock:
            return len(self._heap)

    @property
    def occupied(self) -> int:
        with self._lock:
            return sum(r is not None for r in self.slots)

    @property
    def load(self) -> int:
        """Queue depth analogue for least-loaded dispatch across replicas."""
        with self._lock:
            return len(self._heap) + sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        with self._lock:
            return bool(self._heap) or any(r is not None for r in self.slots)

    def wait_for_work(self, timeout: float | None = None) -> bool:
        with self._work:
            if self.has_work():
                return True
            self._work.wait(timeout)
            return self.has_work()
