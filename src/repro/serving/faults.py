"""Deterministic, seedable fault injection for the serving stack.

A fleet of sub-1W co-processors fails individually by design; the paper's
deployment targets (space, edge) make faults the *expected* case rather
than the exception.  This module is the harness that lets every recovery
path in the serving stack be provoked on demand, in-process, inside CI:

  * :class:`FaultSpec` — one injection: a *site* (a named probe point in
    the stack), an *action* (raise / drop / delay), an arrival window
    (skip the first ``after`` matching arrivals, then fire ``count``
    times), and optional request-id / replica filters.
  * :class:`FaultPlan` — an ordered list of specs plus the thread-safe
    ``fire()`` probe the stack calls at each site.  Plans are plain data:
    the same plan against the same workload injects the same faults in
    the same order, so every chaos test is reproducible bit-for-bit.
  * The typed failure vocabulary (:class:`FaultError`,
    :class:`ShedError`, :class:`DeadlineExceeded`,
    :class:`ExecutorCrash`) shared by the engine and router so callers
    can distinguish an injected fault from load shedding from a deadline
    miss from a dead executor.

Probe sites (the closed vocabulary, validated at plan construction):

  ``target.compute``    offload Target worker, before execute
  ``engine.prefill``    one request's prefill chunk, before compute
  ``engine.decode``     one request's decode commit, before the token
                        lands in ``req.output``
  ``kv.spill``          tiered-KV spill transfer (drop/delay only —
                        the submit happens under pool-adjacent state,
                        so a raise would be a crash, not a fault)
  ``kv.fetch``          tiered-KV fetch transfer (drop/delay only;
                        a drop exercises the recompute fallback)
  ``kv.migrate``        prefill→decode KV-block migration transfer
                        (drop/delay only; a drop loses the handoff and
                        exercises the retry-from-bare-prompt path)
  ``replica.executor``  top of one executor step — a raise here kills
                        the whole replica (the crash-capture path)

The ``drop`` action means "pretend the work silently produced nothing":
at transfer sites the result becomes a tier miss; at compute sites the
item completes with ``None``.  ``delay`` sleeps ``delay_s`` and then
proceeds — enough to trip deadlines and straggler reissue.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field


class FaultError(RuntimeError):
    """An injected fault (the ``raise`` action) at a named site."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}"
                         + (f": {detail}" if detail else ""))
        self.site = site


class ShedError(RuntimeError):
    """Admission rejected: queue depth guarantees an SLO miss."""


class DeadlineExceeded(RuntimeError):
    """The request's ``deadline_s`` elapsed before completion."""


class ExecutorCrash(RuntimeError):
    """A replica's executor thread died on a non-request fault."""


SITES = (
    "target.compute",
    "engine.prefill",
    "engine.decode",
    "kv.spill",
    "kv.fetch",
    "kv.migrate",
    "replica.executor",
)

ACTIONS = ("raise", "drop", "delay")

# transfer sites run under pool-adjacent state where a raise would be an
# engine crash rather than an isolable per-request fault
_NO_RAISE_SITES = ("kv.spill", "kv.fetch", "kv.migrate")


@dataclass
class FaultSpec:
    """One injection: fire ``action`` on matching arrivals at ``site``,
    skipping the first ``after`` and then firing ``count`` times."""
    site: str
    action: str = "raise"
    after: int = 0
    count: int = 1
    delay_s: float = 0.0
    rid: str | None = None        # only arrivals for this request id
    replica: str | None = None    # only arrivals on this replica/engine

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites are {SITES}")
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"actions are {ACTIONS}")
        if self.site in _NO_RAISE_SITES and self.action == "raise":
            raise ValueError(f"site {self.site} supports only drop/delay "
                             f"(a raise there is a crash, not a fault)")
        if self.after < 0 or self.count < 1:
            raise ValueError("after must be >= 0 and count >= 1")


@dataclass
class FaultPlan:
    """An ordered set of :class:`FaultSpec` plus the thread-safe probe.

    ``fire(site, rid=..., replica=...)`` returns the first spec whose
    filters match and whose arrival window is open, bumping the global
    ``injected`` counter; ``None`` means "no fault here".  Arrival
    counting is per-spec and global across threads (one lock), so a plan
    shared by several replicas still fires deterministically with
    respect to each spec's own arrival stream.
    """
    specs: list[FaultSpec] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._seen = [0] * len(self.specs)
        self.injected = 0          # guarded-by: self._lock

    def __bool__(self) -> bool:
        return bool(self.specs)

    def fire(self, site: str, *, rid: str | None = None,
             replica: str | None = None) -> FaultSpec | None:
        if not self.specs:
            return None
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if spec.rid is not None and spec.rid != rid:
                    continue
                if spec.replica is not None and spec.replica != replica:
                    continue
                self._seen[i] += 1
                if spec.after < self._seen[i] <= spec.after + spec.count:
                    self.injected += 1
                    return spec
            return None

    @property
    def fired(self) -> int:
        with self._lock:
            return self.injected

    @classmethod
    def from_seed(cls, seed: int, n: int = 3,
                  sites: tuple[str, ...] = SITES,
                  max_after: int = 8, max_count: int = 2,
                  max_delay_s: float = 0.002) -> "FaultPlan":
        """A deterministic random plan: ``n`` specs over ``sites`` with
        random actions and arrival windows.  Same seed, same plan."""
        rng = random.Random(seed)
        specs: list[FaultSpec] = []
        for _ in range(n):
            site = rng.choice(sites)
            actions = [a for a in ACTIONS
                       if not (site in _NO_RAISE_SITES and a == "raise")]
            action = rng.choice(actions)
            specs.append(FaultSpec(
                site=site, action=action,
                after=rng.randrange(max_after),
                count=1 + rng.randrange(max_count),
                delay_s=rng.uniform(0.0, max_delay_s)
                if action == "delay" else 0.0))
        return cls(specs)

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """CLI syntax: ``site[:action[:after[:count]]]`` comma-separated,
        or ``seed=<int>`` for a random plan — e.g.
        ``replica.executor:raise:4,kv.fetch:drop`` or ``seed=7``."""
        text = text.strip()
        if not text:
            return cls([])
        if text.startswith("seed="):
            return cls.from_seed(int(text[5:]))
        specs = []
        for part in text.split(","):
            bits = part.strip().split(":")
            spec = FaultSpec(
                site=bits[0],
                action=bits[1] if len(bits) > 1 else "raise",
                after=int(bits[2]) if len(bits) > 2 else 0,
                count=int(bits[3]) if len(bits) > 3 else 1)
            specs.append(spec)
        return cls(specs)
