"""Continuous-batching LM serving engine (scheduler/executor split).

The paper's multi-NCS pattern at LM scale: a *replica group* (one model
replica, possibly TP/EP-sharded over a submesh) plays the role of one NCS
device.  Within a replica, :class:`ServingEngine` is the executor for a
:class:`~repro.serving.scheduler.ContinuousScheduler`: it keeps a fixed-slot
decode batch alive and refills a slot with a chunked prefill the moment its
request finishes — no lock-step waves, no length bucketing.  Across
replicas, :class:`MultiReplicaEngine` has each replica pull individual
requests from a shared queue through `repro.core.offload`'s split-phase
protocol (least-loaded dispatch, out-of-order collection), so a slow
request on one replica never blocks completions elsewhere.

Request lifecycle: QUEUED -> PREFILL -> DECODE -> DONE (see scheduler.py).
Per-slot KV state lives in one batched decode-state pytree; a finished
slot's cache lines are overwritten in place by the next request's prefill
(`_merge_slot` writes along the batch axis of every state leaf).

`serve_wave` preserves the seed's lock-step wave decode for A/B comparison
in `benchmarks/serving_bench.py`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import OffloadEngine, Target, WorkItem
from repro.models.registry import fns_for
from repro.serving.scheduler import ContinuousScheduler, Request, RequestState
from repro.serving.sampler import Sampler  # noqa: F401 (re-export)


@dataclass
class ServeStats:
    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    prefills: int = 0
    decode_steps: int = 0
    occupancy_sum: float = 0.0          # sum over decode steps of active/slots
    ttft: list = field(default_factory=list)    # per-request seconds
    tpot: list = field(default_factory=list)    # per-request seconds/token

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of decode slots doing useful work per decode step."""
        return self.occupancy_sum / self.decode_steps if self.decode_steps \
            else 0.0

    @property
    def ttft_p50_s(self) -> float | None:
        return float(np.percentile(self.ttft, 50)) if self.ttft else None

    @property
    def ttft_p99_s(self) -> float | None:
        return float(np.percentile(self.ttft, 99)) if self.ttft else None

    @property
    def mean_tpot_s(self) -> float | None:
        return float(np.mean(self.tpot)) if self.tpot else None

    def fill_request_metrics(self, requests: list[Request]) -> None:
        for r in requests:
            if r.ttft_s is not None:
                self.ttft.append(r.ttft_s)
            if r.tpot_s is not None:
                self.tpot.append(r.tpot_s)


def _merge_slot(state, slot_state, slot: jax.Array):
    """Write a single-request decode state into slot ``slot`` of the batched
    state.  Both pytrees come from the same model fns with the same
    ``max_len`` and differ only in batch size, so for every leaf the batch
    axis is the unique axis where the shapes differ."""
    def leaf(big, small):
        if big.shape == small.shape:        # num_slots == 1
            return small.astype(big.dtype)
        axis = next(a for a in range(big.ndim)
                    if big.shape[a] != small.shape[a])
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis)
    return jax.tree_util.tree_map(leaf, state, slot_state)


class ServingEngine:
    """One replica: continuous batching over a fixed-slot decode batch.

    Two driving modes share the same executor step:

      * :meth:`serve` — blocking: admit a list of requests, run until all
        are DONE (the benchmark / offline path).
      * :meth:`start` / :meth:`submit` / :meth:`stop` — service mode: a
        background executor thread drains the admission queue as requests
        stream in (the multi-replica pull-loop and live-traffic path).
    """

    def __init__(self, cfg, params, *, max_len: int = 256,
                 batch_slots: int = 4, chunk: int = 512):
        self.cfg = cfg
        self.params = params
        self.fns = fns_for(cfg)
        self.max_len = max_len
        self.slots = batch_slots
        self.chunk = chunk
        self.scheduler = ContinuousScheduler(batch_slots)
        self._decode = jax.jit(
            lambda p, t, s: self.fns.decode(cfg, p, t, s, chunk=chunk))
        # jitted prefill, shape-keyed: one compile per (batch, prompt-len)
        # signature — the continuous path always prefills batch 1, so slot
        # refills never pay an eager-dispatch tax.
        self._prefill = jax.jit(
            lambda p, b: self.fns.prefill(cfg, p, b, max_len=max_len,
                                          chunk=chunk))
        self._merge = jax.jit(_merge_slot)
        self._state = None                   # batched decode-state pytree
        self._last: np.ndarray | None = None  # (slots, V) last logits
        self.totals = ServeStats()           # lifetime counters (monotonic)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- model plumbing --------------------------------------------------------

    def _check_fits(self, req: Request) -> None:
        """Reject requests that would overrun the per-slot KV capacity —
        out-of-range cache writes clamp/drop silently under jit, corrupting
        generation instead of failing."""
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len + 1:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new_tokens {req.max_new_tokens} exceeds KV capacity "
                f"max_len={self.max_len}")

    def _batch_for(self, prompts: np.ndarray) -> dict:
        """prompts: (W, S) -> model batch dict (positions/frames as needed)."""
        W, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.m_rope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (W, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, W, S))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (W, self.cfg.encdec.num_encoder_frames, self.cfg.d_model),
                jnp.float32)
        return batch

    def _prefill_one(self, req: Request):
        """Chunked prefill of one prompt -> ((V,) logits, batch-1 state)."""
        batch = self._batch_for(req.prompt[None])
        last, state = self._prefill(self.params, batch)
        return np.asarray(last[0]), state

    def _init_state(self):
        """Batched decode-state template covering all slots."""
        return self.fns.init_decode_state(self.cfg, self.slots, self.max_len)

    # -- executor step ---------------------------------------------------------

    def _sample_active(self, active: list[tuple[int, Request]]) -> dict[int, int]:
        """Vectorized sampling: group slots by sampler batch_key, one
        `sample` call per group (one argmax for the whole batch when all
        slots are greedy)."""
        groups: dict = {}
        for slot, req in active:
            groups.setdefault(req.sampler.batch_key, []).append((slot, req))
        toks: dict[int, int] = {}
        for members in groups.values():
            rows = np.array([s for s, _ in members])
            out = members[0][1].sampler.sample(self._last[rows])
            for (slot, _), tok in zip(members, out):
                toks[slot] = int(tok)
        return toks

    def _step(self) -> bool:
        """One executor iteration: refill free slots (chunked prefill),
        sample one token per active slot (vectorized), advance the batched
        decode step.  Returns False when there was no work."""
        for slot, req in self.scheduler.admit():
            last1, state1 = self._prefill_one(req)
            self.totals.prefills += 1
            if self._state is None:
                self._state = self._init_state()
                self._last = np.zeros((self.slots, last1.shape[-1]),
                                      last1.dtype)
            self._state = self._merge(self._state, state1,
                                      jnp.int32(slot))
            if not self._last.flags.writeable:  # np view of a jax buffer
                self._last = self._last.copy()
            self._last[slot] = last1
            req.state = RequestState.DECODE

        active = self.scheduler.active()
        if not active:
            return False

        toks = self._sample_active(active)
        now = time.monotonic()
        feed = np.zeros((self.slots,), np.int32)
        for slot, req in active:
            tok = toks[slot]
            feed[slot] = tok
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.append(tok)
            self.totals.tokens += 1
            if len(req.output) >= req.max_new_tokens:
                req.state = RequestState.DONE
                req.finished_at = time.monotonic()
                self.scheduler.release(slot)
                if req.on_finish is not None:
                    req.on_finish(req)

        still = self.scheduler.active()
        if still:        # someone needs next-token logits
            last, self._state = self._decode(
                self.params, jnp.asarray(feed)[:, None], self._state)
            self._last = np.asarray(last)
            self.totals.decode_steps += 1
            self.totals.occupancy_sum += len(still) / self.slots
        return True

    # -- blocking mode ---------------------------------------------------------

    def serve(self, requests: list[Request]) -> ServeStats:
        """Continuous batching: admit everything, run the executor until
        every request is DONE."""
        assert self._thread is None, "engine already running in service mode"
        for r in requests:
            self._check_fits(r)
        base = (self.totals.tokens, self.totals.prefills,
                self.totals.decode_steps, self.totals.occupancy_sum)
        t0 = time.monotonic()
        for r in requests:
            self.scheduler.submit(r)
        while self.scheduler.has_work():
            self._step()
        stats = ServeStats(requests=len(requests),
                           wall_s=time.monotonic() - t0)
        stats.tokens = self.totals.tokens - base[0]
        stats.prefills = self.totals.prefills - base[1]
        stats.decode_steps = self.totals.decode_steps - base[2]
        stats.occupancy_sum = self.totals.occupancy_sum - base[3]
        stats.fill_request_metrics(requests)
        return stats

    # -- service mode (used by MultiReplicaEngine and live traffic) ------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._service_loop, daemon=True)
        self._thread.start()

    def _service_loop(self) -> None:
        while not self._stop.is_set():
            if not self.scheduler.wait_for_work(timeout=0.02):
                continue
            self._step()

    def submit(self, req: Request,
               on_finish: Callable[[Request], None] | None = None) -> None:
        """Thread-safe admission; ``on_finish`` fires from the executor
        thread the moment the request's last token is emitted."""
        self._check_fits(req)
        if on_finish is not None:
            req.on_finish = on_finish
        self.scheduler.submit(req)

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10)
        self._thread = None

    @property
    def load(self) -> int:
        return self.scheduler.load

    # -- legacy wave decode (seed behaviour, kept for A/B benchmarking) --------

    def serve_wave(self, requests: list[Request]) -> ServeStats:
        """The seed's lock-step path: bucket by prompt length, prefill each
        wave batched, decode until every wave member finishes.  A finished
        slot idles until the slowest request in its wave completes — kept
        only as the baseline `benchmarks/serving_bench.py` compares
        continuous batching against."""
        for r in requests:
            self._check_fits(r)
        stats = ServeStats(requests=len(requests))
        t0 = time.monotonic()
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for _, bucket in sorted(buckets.items()):
            for w0 in range(0, len(bucket), self.slots):
                wave = bucket[w0:w0 + self.slots]
                prompts = np.stack([r.prompt for r in wave])
                last, state = self._prefill(self.params,
                                            self._batch_for(prompts))
                stats.prefills += 1
                active = np.ones(len(wave), bool)
                n_steps = max(r.max_new_tokens for r in wave)
                for _ in range(n_steps):
                    toks = []
                    for i, r in enumerate(wave):
                        tok = int(r.sampler(np.asarray(last[i])))
                        if active[i]:
                            if r.first_token_at is None:
                                r.first_token_at = time.monotonic()
                            r.output.append(tok)
                            stats.tokens += 1
                            if len(r.output) >= r.max_new_tokens:
                                active[i] = False
                                r.state = RequestState.DONE
                                r.finished_at = time.monotonic()
                        toks.append(tok)
                    if not active.any():
                        break
                    last, state = self._decode(
                        self.params, jnp.asarray(toks, jnp.int32)[:, None],
                        state)
                    stats.decode_steps += 1
                    stats.occupancy_sum += active.sum() / self.slots
        stats.wall_s = time.monotonic() - t0
        stats.fill_request_metrics(requests)
        return stats


class ReplicaTarget(Target):
    """Adapter: one continuous-batching replica as an offload Target.

    `load_tensor` (the paper's mvncLoadTensor) admits a request clone into
    the replica's scheduler and returns immediately; the replica's executor
    thread plays the role of the per-NCS worker, and `WorkItem.complete`
    fires when the request's last token is emitted.  `queue_depth` exposes
    scheduler load (queued + occupied slots) so the offload engine's
    least-loaded dispatch balances individual requests across replicas.
    """

    def __init__(self, engine: ServingEngine, name: str,
                 tdp_watts: float = 1.0):
        self.engine = engine
        self.name = name
        self.tdp_watts = tdp_watts

    def open(self) -> None:
        self.busy = False
        self.engine.start()

    def close(self) -> None:
        self.engine.stop()

    def load_tensor(self, item: WorkItem) -> WorkItem:
        req = item.payload.clone()      # reissue-safe: first clone wins
        self.engine.submit(req, on_finish=lambda r: item.complete(r, self.name))
        return item

    @property
    def queue_depth(self) -> int:
        return self.engine.load


class MultiReplicaEngine:
    """Replicas pull individual requests from a shared queue (paper's
    multi-NCS, continuous-batching edition).

    Each replica is a :class:`ServingEngine` wrapped in a
    :class:`ReplicaTarget`; `repro.core.offload` provides the split-phase
    submit, least-loaded dispatch, out-of-order completion drain, and
    deadline-based straggler reissue (a request stuck on one replica is
    re-admitted on the least-loaded one; first finish wins).
    """

    def __init__(self, replicas: list[ServingEngine], *,
                 deadline_s: float | None = None):
        self.replicas = replicas
        self.targets = [ReplicaTarget(e, name=f"replica{i}")
                        for i, e in enumerate(replicas)]
        self.deadline_s = deadline_s

    def serve(self, requests: list[Request], *,
              group_size: int | None = None) -> ServeStats:
        """Least-loaded dispatch of *individual* requests with out-of-order
        collection.  ``group_size`` is deprecated (pre-chunked groups are
        gone); when given it only scales the dispatch window."""
        total_slots = sum(e.slots for e in self.replicas)
        window = (group_size * len(self.replicas) if group_size
                  else 2 * total_slots)
        base = [(e.totals.prefills, e.totals.decode_steps,
                 e.totals.occupancy_sum) for e in self.replicas]
        t0 = time.monotonic()
        with OffloadEngine(self.targets, scheduler="least_loaded",
                           deadline_s=self.deadline_s) as eng:
            results, ostats = eng.run_unordered(requests, window=window)
        stats = ServeStats(requests=len(requests),
                           wall_s=time.monotonic() - t0)
        for seq, done in results:      # copy the winning clone's results back
            orig = requests[seq]
            orig.output = done.output
            orig.state = done.state
            orig.first_token_at = done.first_token_at
            orig.finished_at = done.finished_at
            stats.tokens += len(done.output)
        for e, (p0, d0, o0) in zip(self.replicas, base):
            stats.prefills += e.totals.prefills - p0
            stats.decode_steps += e.totals.decode_steps - d0
            stats.occupancy_sum += e.totals.occupancy_sum - o0
        stats.fill_request_metrics(requests)
        return stats
