"""Batched LM serving engine.

The paper's multi-NCS pattern at LM scale: a *replica group* (one model
replica, possibly TP/EP-sharded over a submesh) plays the role of one NCS
device; the engine keeps a fixed-slot decode batch per replica
(continuous batching), prefills arrivals into free slots, and round-robins
request streams across replica groups via `repro.core.offload`.

Single-replica path (`ServingEngine`) is fully functional on CPU; the
multi-replica path wraps each replica in a `JaxTarget` so the paper's
split-phase load/collect protocol carries over unchanged.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import JaxTarget, OffloadEngine
from repro.models.registry import fns_for
from repro.serving.sampler import Sampler, greedy


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    sampler: Sampler = field(default_factory=greedy)
    # filled by the engine:
    output: list = field(default_factory=list)
    submitted_at: float = field(default_factory=time.monotonic)
    first_token_at: float | None = None
    finished_at: float | None = None

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at


@dataclass
class ServeStats:
    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    prefills: int = 0
    decode_steps: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0


class ServingEngine:
    """One replica: prefill-then-batched-decode with fixed slots."""

    def __init__(self, cfg, params, *, max_len: int = 256,
                 batch_slots: int = 4, chunk: int = 512):
        self.cfg = cfg
        self.params = params
        self.fns = fns_for(cfg)
        self.max_len = max_len
        self.slots = batch_slots
        self.chunk = chunk
        self._decode = jax.jit(
            lambda p, t, s: self.fns.decode(cfg, p, t, s, chunk=chunk))

    def _prefill_wave(self, prompts: np.ndarray):
        """prompts: (W, S) equal-length bucket -> (last logits, state)."""
        W, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.m_rope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (W, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, W, S))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (W, self.cfg.encdec.num_encoder_frames, self.cfg.d_model),
                jnp.float32)
        return self.fns.prefill(self.cfg, self.params, batch,
                                max_len=self.max_len, chunk=self.chunk)

    def serve(self, requests: list[Request]) -> ServeStats:
        """Bucket by prompt length, prefill each wave batched, decode in
        lock-step until every wave member finishes.  Continuous batching
        across replicas is handled by `MultiReplicaEngine`."""
        stats = ServeStats(requests=len(requests))
        t0 = time.monotonic()
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for _, bucket in sorted(buckets.items()):
            for w0 in range(0, len(bucket), self.slots):
                wave = bucket[w0:w0 + self.slots]
                prompts = np.stack([r.prompt for r in wave])
                last, state = self._prefill_wave(prompts)
                stats.prefills += 1
                active = np.ones(len(wave), bool)
                n_steps = max(r.max_new_tokens for r in wave)
                for _ in range(n_steps):
                    toks = []
                    for i, r in enumerate(wave):
                        tok = int(r.sampler(np.asarray(last[i])))
                        if active[i]:
                            if r.first_token_at is None:
                                r.first_token_at = time.monotonic()
                            r.output.append(tok)
                            stats.tokens += 1
                            if len(r.output) >= r.max_new_tokens:
                                active[i] = False
                                r.finished_at = time.monotonic()
                        toks.append(tok)
                    if not active.any():
                        break
                    last, state = self._decode(
                        self.params, jnp.asarray(toks, jnp.int32)[:, None],
                        state)
                    stats.decode_steps += 1
        stats.wall_s = time.monotonic() - t0
        return stats


class MultiReplicaEngine:
    """Round-robin request dispatch across replica groups (paper's multi-NCS).

    Each replica is a `ServingEngine` wrapped in a `JaxTarget`; the offload
    engine provides the split-phase submit/collect and straggler reissue.
    """

    def __init__(self, replicas: list[ServingEngine], *,
                 deadline_s: float | None = None):
        self.replicas = replicas

        def make_fn(eng: ServingEngine) -> Callable:
            def fn(reqs: list[Request]):
                st = eng.serve(reqs)
                return {"outputs": [r.output for r in reqs],
                        "tokens": st.tokens, "wall_s": st.wall_s}
            return fn

        self.targets = [JaxTarget(make_fn(e), name=f"replica{i}")
                        for i, e in enumerate(self.replicas)]
        self.deadline_s = deadline_s

    def serve(self, requests: list[Request], *,
              group_size: int = 4) -> ServeStats:
        groups = [requests[i:i + group_size]
                  for i in range(0, len(requests), group_size)]
        t0 = time.monotonic()
        with OffloadEngine(self.targets,
                           deadline_s=self.deadline_s) as eng:
            results, _ = eng.run(groups)
        stats = ServeStats(requests=len(requests))
        stats.tokens = sum(r["tokens"] for r in results)
        stats.wall_s = time.monotonic() - t0
        return stats
