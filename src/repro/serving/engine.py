"""Continuous-batching LM serving engine (scheduler/executor split).

The paper's multi-NCS pattern at LM scale: a *replica group* (one model
replica, possibly TP/EP-sharded over a submesh) plays the role of one NCS
device.  Within a replica, :class:`ServingEngine` is the executor for a
:class:`~repro.serving.scheduler.ContinuousScheduler`: it keeps a fixed-slot
decode batch alive and refills a slot with a chunked prefill the moment its
request finishes — no lock-step waves, no length bucketing.  Cross-replica
placement lives in `repro.serving.router`: :class:`~repro.serving.router.
ReplicaRouter` dispatches individual requests with prefix-affinity +
block-aware scoring and steals queued work back onto idle replicas
(``MultiReplicaEngine`` / ``ReplicaTarget`` moved there; importing them
from this module still works but warns).

Request lifecycle: QUEUED -> PREFILL -> DECODE -> DONE (see scheduler.py).

KV state comes in two layouts:

  * **paged** (default for transformer families): one global
    :class:`~repro.serving.kv_pool.KVBlockPool` of fixed-size KV blocks
    shared by every slot, per-request block tables, block-aware admission,
    and power-of-two *prompt-length bucketing* so the jitted prefill
    compiles once per bucket instead of once per length.  Decode attention
    gathers only live blocks (Pallas paged kernel on TPU, jnp oracle
    elsewhere), so neither HBM nor decode reads pay worst-case ``max_len``
    per slot.  On top of the pool the engine layers **SLO-aware
    scheduling** — priority admission with recompute-style preemption of
    lower-priority decodes under block pressure (the victim's generated
    tokens fold into its prompt and re-prefill through the bucketed path)
    — and **prefix sharing**: a prefix index maps the token content of
    full leading prompt blocks to refcounted pool blocks, so requests with
    a common prompt prefix point their leading table entries at one shared
    copy and allocate only their tail.  Prefill is **cache-seeded and
    chunked**: prompt KV is written *directly* into pool blocks by
    ``prefill_paged`` (no dense bucket cache + scatter round-trip), and
    computation starts at the first unseeded token — a shared prefix or a
    preemption-surviving history is read through the block table, never
    re-run.  A ``prefill_chunk`` budget splits long prompts into
    fixed-size chunks interleaved with decode steps, so one huge prompt
    no longer stalls every active decode for its whole prefill
    (SARATHI-style chunked prefill; the stall shows up as
    ``decode_gaps`` / ``decode_stall_p99_s`` in :class:`ServeStats`).
  * **contiguous** (``paged=False`` and non-transformer families): the
    PR-1 layout — a worst-case ``(L, slots, max_len, K, D)`` state whose
    batch axis is overwritten in place per refill (`_merge_slot`).

`serve_wave` preserves the seed's lock-step wave decode for A/B comparison
in `benchmarks/serving_bench.py`.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field, fields
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import WorkError
from repro.models.registry import fns_for
from repro.serving.faults import (DeadlineExceeded, ExecutorCrash,
                                  FaultError, FaultPlan, ShedError)
from repro.serving.kv_pool import CapacityError, KVBlockPool
from repro.serving.scheduler import (ContinuousScheduler, LoadSnapshot,
                                     Request, RequestState)
from repro.serving.sampler import Sampler  # noqa: F401 (re-export)
from repro.serving.sampler import greedy_accept_prefix


# Declarative multi-replica merge spec: every ServeStats field MUST have a
# rule here — tests/test_router.py enforces the bijection — so a new field
# can never silently vanish from fleet aggregation (the bug class behind
# PR-3's "pool peaks never populated" fix, previously re-invitable by any
# field added to ServeStats but not to the hand-written merge loop).
#   sum      — additive counter
#   max      — window-level maximum (wall clock)
#   extend   — per-request / per-step sample lists, concatenated
#   opt_sum  — None-aware sum: stays None only when every input is None
#   derived  — a ratio recomputed inside merge_from from already-merged
#              numerators/denominators via _DERIVED (never copied or
#              averaged across: a ratio of sums is not a sum of ratios)
MERGE_RULES: dict[str, str] = {
    "requests": "sum",
    "tokens": "sum",
    "wall_s": "max",
    "prefills": "sum",
    "decode_steps": "sum",
    "verify_steps": "sum",
    "occupancy_sum": "sum",
    "prefill_compiles": "sum",
    "preemptions": "sum",
    "prefix_shared_blocks": "sum",
    "slo_tracked": "sum",
    "slo_misses": "sum",
    "prefill_tokens_total": "sum",
    "prefill_tokens_computed": "sum",
    "router_steals": "sum",
    "router_affinity_hits": "sum",
    "spec_proposed": "sum",
    "spec_accepted": "sum",
    "accept_rate": "derived",       # merged accepted / merged proposed
    "kv_spills": "sum",
    "kv_fetches": "sum",
    "prefix_hits_host": "sum",
    "prefix_lookups": "sum",
    "spill_bytes": "sum",
    "kv_hit_rate": "derived",       # merged (device + host hits) / lookups
    "kv_blocks_peak": "opt_sum",
    "kv_pool_capacity": "opt_sum",
    "kv_pool_util": "derived",      # merged peak / combined capacity
    "requests_failed": "sum",
    "requests_retried": "sum",
    "replica_failures": "sum",
    "shed_rejections": "sum",
    "faults_injected": "sum",
    "kv_migrations": "sum",
    "migrated_blocks": "sum",
    "ttft": "extend",
    "tpot": "extend",
    "decode_gaps": "extend",
}

# Recompute functions for every "derived" rule above, applied by
# merge_from after the field-by-field fold (tests enforce the bijection
# with MERGE_RULES).  Historically the *caller* was expected to recompute
# these post-merge; the one caller that remembered (the router) only knew
# about kv_pool_util, so any other merge path kept the first window's
# stale ratio — hence: derive inside the merge, from merged parts.
_DERIVED: dict[str, Callable[["ServeStats"], float | None]] = {
    "kv_pool_util": lambda s: (
        s.kv_blocks_peak / s.kv_pool_capacity
        if s.kv_blocks_peak is not None and s.kv_pool_capacity else None),
    "accept_rate": lambda s: (
        s.spec_accepted / s.spec_proposed if s.spec_proposed else None),
    "kv_hit_rate": lambda s: (
        (s.prefix_shared_blocks + s.prefix_hits_host) / s.prefix_lookups
        if s.prefix_lookups else None),
}


@dataclass
class ServeStats:
    requests: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    prefills: int = 0
    decode_steps: int = 0
    verify_steps: int = 0               # speculative multi-token target passes
    occupancy_sum: float = 0.0          # sum over decode-cadence steps
                                        # (decode + verify) of active/slots
    prefill_compiles: int = 0           # distinct jitted prefill signatures
    preemptions: int = 0                # decode evictions under queue pressure
    prefix_shared_blocks: int = 0       # table entries mapped to shared blocks
    slo_tracked: int = 0                # requests carrying a TTFT SLO
    slo_misses: int = 0                 # ... whose TTFT exceeded it
    prefill_tokens_total: int = 0       # tokens a full recompute would run
    prefill_tokens_computed: int = 0    # tokens actually run (rest seeded)
    router_steals: int = 0              # requests migrated to an idle replica
    router_affinity_hits: int = 0       # requests routed onto their prefix
    spec_proposed: int = 0              # drafter tokens offered to verify
    spec_accepted: int = 0              # ... committed (matched target argmax)
    accept_rate: float | None = None    # spec only: accepted / proposed
    kv_spills: int = 0                  # tiered: blocks demoted to host tier
    kv_fetches: int = 0                 # tiered: host blocks restored to pool
    prefix_hits_host: int = 0           # tiered: prefix blocks seeded via fetch
    prefix_lookups: int = 0             # full prompt blocks probed in the index
    spill_bytes: int = 0                # tiered: bytes moved device -> host
    kv_hit_rate: float | None = None    # (device + host prefix hits) / lookups
    kv_blocks_peak: int | None = None   # paged only: peak pool blocks in use
    kv_pool_capacity: int | None = None  # paged only: pool size in blocks
    kv_pool_util: float | None = None   # paged only: peak / capacity
    requests_failed: int = 0            # terminal FAILED (poison/deadline/
                                        # retries exhausted)
    requests_retried: int = 0           # reissued to a survivor replica
    replica_failures: int = 0           # request failures charged to replicas
    shed_rejections: int = 0            # admissions refused (queue too deep)
    faults_injected: int = 0            # fault-plan probes that fired here
    kv_migrations: int = 0              # disagg: prefills adopted from a peer
    migrated_blocks: int = 0            # disagg: pool blocks landed via adopt
    ttft: list = field(default_factory=list)    # per-request seconds
    tpot: list = field(default_factory=list)    # per-request seconds/token
    decode_gaps: list = field(default_factory=list)  # s between decode steps

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of decode slots doing useful work per decode-
        cadence step (vanilla decode or speculative verify)."""
        steps = self.decode_steps + self.verify_steps
        return self.occupancy_sum / steps if steps else 0.0

    @property
    def steps_per_token(self) -> float | None:
        """Batched target-model passes (decode + verify) per generated
        token — the raw-speed number speculative decoding moves: a verify
        pass can commit several tokens per slot, so spec pushes this below
        the vanilla value for the same workload."""
        steps = self.decode_steps + self.verify_steps
        return steps / self.tokens if self.tokens else None

    @property
    def ttft_p50_s(self) -> float | None:
        return float(np.percentile(self.ttft, 50)) if self.ttft else None

    @property
    def ttft_p99_s(self) -> float | None:
        return float(np.percentile(self.ttft, 99)) if self.ttft else None

    @property
    def mean_tpot_s(self) -> float | None:
        return float(np.mean(self.tpot)) if self.tpot else None

    @property
    def prefill_compute_frac(self) -> float | None:
        """Fraction of prefill tokens actually computed (1.0 = nothing was
        seeded from the cache); None when no prefill happened."""
        return (self.prefill_tokens_computed / self.prefill_tokens_total
                if self.prefill_tokens_total else None)

    @property
    def decode_stall_p99_s(self) -> float | None:
        """p99 wall-clock gap between consecutive decode steps while
        decodes were active — a long un-chunked prefill of a newly
        admitted prompt shows up here as one giant gap."""
        return (float(np.percentile(self.decode_gaps, 99))
                if self.decode_gaps else None)

    @property
    def slo_miss_rate(self) -> float | None:
        """Fraction of SLO-carrying requests whose TTFT missed; None when
        the workload carries no SLOs."""
        return self.slo_misses / self.slo_tracked if self.slo_tracked \
            else None

    def merge_from(self, sub: "ServeStats") -> "ServeStats":
        """Fold another window's stats into this one, field by field, under
        :data:`MERGE_RULES`.  Raises on a field without a rule, so adding a
        ``ServeStats`` field without deciding its fleet semantics fails the
        first multi-replica aggregation (and the rule-coverage test)
        instead of silently dropping the field."""
        for f in fields(self):
            rule = MERGE_RULES.get(f.name)
            if rule is None:
                raise ValueError(
                    f"ServeStats field {f.name!r} has no merge rule; add "
                    f"it to MERGE_RULES (sum/max/extend/opt_sum/derived)")
            a, b = getattr(self, f.name), getattr(sub, f.name)
            if rule == "sum":
                setattr(self, f.name, a + b)
            elif rule == "max":
                setattr(self, f.name, max(a, b))
            elif rule == "extend":
                a.extend(b)
            elif rule == "opt_sum":
                if b is not None:
                    setattr(self, f.name, (a or 0) + b)
            elif rule == "derived":
                pass                     # recomputed below from merged parts
            else:
                raise ValueError(f"unknown merge rule {rule!r} "
                                 f"for ServeStats.{f.name}")
        # derived ratios recompute from the merged numerators/denominators
        # (copying or averaging per-window ratios would weight every window
        # equally regardless of size)
        for name, fn in _DERIVED.items():
            setattr(self, name, fn(self))
        return self

    def fill_request_metrics(self, requests: list[Request]) -> None:
        for r in requests:
            if r.ttft_s is not None:
                self.ttft.append(r.ttft_s)
            if r.tpot_s is not None:
                self.tpot.append(r.tpot_s)
            if r.slo_ttft_s is not None:
                # an SLO request that never produced a token inside the
                # window missed by definition — excluding it would let the
                # worst outcomes deflate the miss rate
                self.slo_tracked += 1
                self.slo_misses += int(r.slo_miss is not False)


class WindowBase(NamedTuple):
    """Lifetime-counter snapshot anchoring a serving measurement window
    (:meth:`ServingEngine.begin_window` / ``collect_window``)."""
    tokens: int
    prefills: int
    decode_steps: int
    verify_steps: int
    spec_proposed: int
    spec_accepted: int
    occupancy_sum: float
    prefill_compiles: int
    preemptions: int
    prefix_shared: int
    prefill_tokens_total: int
    prefill_tokens_computed: int
    decode_gap_n: int           # lifetime decode-gap count at window start
                                # (incl. entries trimmed from the bounded
                                # totals.decode_gaps list)
    kv_spills: int = 0          # tiering lifetime counters (0 when untiered)
    kv_fetches: int = 0
    prefix_hits_host: int = 0
    prefix_lookups: int = 0
    spill_bytes: int = 0
    requests_failed: int = 0    # fault-tolerance lifetime counters
    shed_rejections: int = 0
    faults_injected: int = 0
    kv_migrations: int = 0      # disagg lifetime counters (0 when mixed)
    migrated_blocks: int = 0


def prefix_digests(tokens: np.ndarray, block_size: int) -> list[bytes]:
    """One chained digest per *full* leading block of ``tokens``: digest
    ``j`` covers the tokens of blocks 0..j.  Chaining keeps the whole key
    list O(prompt) — slicing ``tokens[:(j+1)*bs]`` fresh per key would be
    O(prompt^2) bytes hashed on the executor hot path.

    This is the shared prefix-identity scheme: each engine's per-replica
    prefix index and the :class:`~repro.serving.router.ReplicaRouter`'s
    fleet-level affinity index key on the *same* digests, so "which replica
    already holds this prefix" and "which pool block holds it there" are
    answers to one question."""
    bs = block_size
    h = hashlib.sha1()
    keys: list[bytes] = []
    for j in range(len(tokens) // bs):
        h.update(np.ascontiguousarray(tokens[j * bs:(j + 1) * bs],
                                      dtype=np.int32).tobytes())
        keys.append(h.digest())
    return keys


def _merge_slot(state, slot_state, slot: jax.Array):
    """Write a single-request decode state into slot ``slot`` of the batched
    state.  Both pytrees come from the same model fns with the same
    ``max_len`` and differ only in batch size, so for every leaf the batch
    axis is the unique axis where the shapes differ."""
    def leaf(big, small):
        if big.shape == small.shape:        # num_slots == 1
            return small.astype(big.dtype)
        axis = next(a for a in range(big.ndim)
                    if big.shape[a] != small.shape[a])
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis)
    return jax.tree_util.tree_map(leaf, state, slot_state)


@dataclass
class _PrefillJob:
    """One slot's in-progress cache-seeded chunked prefill.  Blocks are
    *materialized* (prefix lookup + share + alloc) lazily at the first
    chunk, not at admission: jobs advance strictly oldest-first, so by
    the time a job starts computing, every earlier same-step admission
    has completed and published its prefix blocks — chunked mode seeds
    common prefixes exactly like the un-chunked path."""
    req: Request
    tokens: np.ndarray          # prefill_tokens snapshot (prompt + resume)
    nb: int                     # prompt blocks in the request's table
    keys: list                  # prefix digests, published at completion
    pos: int = -1               # rows already in the pool; -1 = blocks
                                # not yet materialized; -2 = materialized
                                # but host-tier fetches still inbound (the
                                # slot is skipped, like a mid-prefill slot,
                                # until _drain_tier commits the last one)
    slot: int = -1              # engine slot (fetch commits validate the
                                # job is still this slot's live prefill)
    prefetch: dict = field(default_factory=dict)   # key -> WorkItem
    pending_n: int = 0          # registered fetches not yet committed
    fetched_ok: set = field(default_factory=set)   # logical blocks restored
    seed_base: int = 0          # device-shared leading blocks (fetch run
                                # extends the seed window past this)


@dataclass
class _Adoption:
    """One migrated prefill staged for executor-side landing: the payload
    :meth:`ServingEngine.adopt_blocks` parks (on the migration worker)
    until :meth:`ServingEngine._admit_paged` pops it at admission and
    lands the rows into freshly allocated pool blocks."""
    req: Request
    keys: list                  # chained prefix digests, full blocks only
    tokens: np.ndarray          # the prefilled token stream (the prompt)
    blocks: list                # per-block host leaf dicts, table order
    last: np.ndarray            # final-chunk next-token logits (V,)


class _Drafter:
    """The drafter side of speculative decoding: a small model with its own
    paged KV pool, mirrored per engine slot.

    The drafter's pool is sized worst-case (every slot at ``max_len`` plus
    the speculative overhang), so drafter allocation can never fail and
    never interacts with the target pool's admission control — the drafter
    is an accelerator, not a tenant.  Per-slot state mirrors the engine's:
    host block tables and valid-row counts, re-injected before every
    batched drafter step.  The drafter lags the target by at most one
    committed token (only after a step that accepted all ``k`` drafts was
    the last committed token never fed to it), and :meth:`propose` feeds
    that gap before the pending token, so drafter KV stays a prefix of
    the committed stream at all times.
    """

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 block_size: int, spec_k: int, chunk: int, cache_dtype: str):
        self.cfg = cfg
        self.params = params
        self.fns = fns_for(cfg)
        if self.fns.init_paged_state is None or self.fns.prefill_paged is None:
            raise ValueError(f"draft family {cfg.family!r} has no paged-KV "
                             f"support; speculative decoding needs it")
        self.slots = slots
        self.block_size = block_size
        self.spec_k = spec_k
        self.max_blocks = -(-(max_len + spec_k + 1) // block_size)
        self.pool = KVBlockPool(slots * self.max_blocks, block_size)
        self._tables = np.zeros((slots, self.max_blocks), np.int32)
        self._lens = np.zeros((slots,), np.int32)
        self._blocks: dict[int, list[int]] = {}
        self._state = self.fns.init_paged_state(
            cfg, self.pool.total_blocks, block_size, slots, self.max_blocks,
            cache_dtype)
        self._decode = jax.jit(
            lambda p, t, s: self.fns.decode(cfg, p, t, s, chunk=chunk))
        self._prefill = jax.jit(
            lambda p, t, s, w, tb, qs, kl, li: self.fns.prefill_paged(
                cfg, p, t, s, w, tb, q_start=qs, kv_len=kl, last_idx=li,
                chunk=chunk))

    def seed(self, slot: int, tokens: np.ndarray, rows: int) -> None:
        """(Re-)prefill the drafter's mirror of a slot: allocate blocks for
        ``rows`` worst-case KV rows (committed budget + overhang) and run
        the prompt — called when the target's prefill completes, including
        after a preemption resume (``tokens`` then carries the folded
        output, exactly like the target's re-prefill)."""
        self.drop(slot)
        bs = self.block_size
        nb = self.pool.blocks_for(rows)
        took = self.pool.reserve(nb)
        assert took, "drafter pool is sized worst-case; reserve cannot fail"
        ids = self.pool.alloc_reserved(nb)
        self._blocks[slot] = ids
        self._tables[slot] = 0
        self._tables[slot, :nb] = ids
        P = len(tokens)
        bucket = bs
        while bucket < P:
            bucket *= 2
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :P] = tokens
        nbp = self.pool.blocks_for(P)
        wids = np.zeros((bucket // bs,), np.int32)
        wids[:nbp] = ids[:nbp]              # padding blocks write to trash
        mb_eff = 1
        while mb_eff < nbp:
            mb_eff *= 2
        mb_eff = min(mb_eff, self.max_blocks)
        tbl = np.zeros((1, mb_eff), np.int32)
        tbl[0, :min(nbp, mb_eff)] = ids[:min(nbp, mb_eff)]
        _, self._state = self._prefill(
            self.params, jnp.asarray(toks), self._state,
            jnp.asarray(wids), jnp.asarray(tbl),
            jnp.asarray([0], jnp.int32), jnp.asarray([P], jnp.int32),
            jnp.int32(P - 1))
        self._lens[slot] = P

    def drop(self, slot: int) -> None:
        """Release a slot's drafter blocks (finish, preemption, re-seed).
        Idempotent: a slot preempted while the target was still prefilling
        was never seeded."""
        ids = self._blocks.pop(slot, None)
        if ids:
            self.pool.free(ids)  # generation-safe: table rows zeroed below
        self._tables[slot] = 0   # trash redirect before the next scatter
        self._lens[slot] = 0

    def set_len(self, slot: int, rows: int) -> None:
        """Post-acceptance bookkeeping: ``rows`` drafter KV rows now hold
        committed-stream tokens (the rejected drafter tail past them is
        simply overwritten by the next propose round)."""
        self._lens[slot] = rows

    def length(self, slot: int) -> int:
        return int(self._lens[slot])

    def propose(self, jobs: list[tuple[int, list[int]]]) -> dict[int, list[int]]:
        """Batched greedy proposal: for each ``(slot, queue)`` job — the
        queue being any committed tokens the drafter has not seen yet plus
        the slot's pending token ``t_0`` — feed the queue, then feed the
        drafter its own argmax continuations until ``k`` proposals exist.
        All jobs advance in lock-step batched (slots, 1) decode steps;
        slots that finish early (shorter queues) write to the trash block.
        """
        k = self.spec_k
        queues = {slot: list(q) for slot, q in jobs}
        drafts: dict[int, list[int]] = {slot: [] for slot, _ in jobs}
        write_pos = {slot: int(self._lens[slot]) for slot, _ in jobs}
        steps = max(len(q) for _, q in jobs) + k - 1
        for _ in range(steps):
            feed = np.zeros((self.slots,), np.int32)
            tbl = np.zeros_like(self._tables)
            lens = np.zeros((self.slots,), np.int32)
            live = []
            for slot, _ in jobs:
                if queues[slot]:
                    tok = queues[slot].pop(0)
                elif len(drafts[slot]) < k:
                    tok = drafts[slot][-1]
                else:
                    continue                 # done: stays trash-targeted
                feed[slot] = tok
                tbl[slot] = self._tables[slot]
                lens[slot] = write_pos[slot]
                write_pos[slot] += 1
                live.append(slot)
            self._state = self._state._replace(
                block_tables=jnp.asarray(tbl), length=jnp.asarray(lens))
            last, self._state = self._decode(
                self.params, jnp.asarray(feed)[:, None], self._state)
            last = np.asarray(last)
            for slot in live:
                if not queues[slot] and len(drafts[slot]) < k:
                    drafts[slot].append(int(np.argmax(last[slot])))
        return drafts


class ServingEngine:
    """One replica: continuous batching over a fixed-slot decode batch.

    Two driving modes share the same executor step:

      * :meth:`serve` — blocking: admit a list of requests, run until all
        are DONE (the benchmark / offline path).
      * :meth:`start` / :meth:`submit` / :meth:`stop` — service mode: a
        background executor thread drains the admission queue as requests
        stream in (the multi-replica pull-loop and live-traffic path).
    """

    def __init__(self, cfg, params, *, max_len: int = 256,
                 batch_slots: int = 4, chunk: int = 512,
                 paged: bool | None = None, block_size: int = 16,
                 pool_blocks: int | None = None,
                 cache_dtype: str = "bfloat16",
                 preemption: bool = True, prefix_sharing: bool = True,
                 prefill_chunk: int | None = None,
                 seeded_prefill: bool = True, host_blocks: int = 0,
                 draft_cfg=None, draft_params=None, spec_k: int = 3,
                 name: str = "", fault_plan: FaultPlan | None = None,
                 shed_queue_depth: int | None = None,
                 role: str = "mixed"):
        self.cfg = cfg
        self.params = params
        # fault tolerance: the replica's name (fault-plan replica filter +
        # router health identity), the injection plan, and the admission
        # shed threshold (queue depth beyond which submit() refuses with
        # ShedError rather than guarantee an SLO miss)
        self.name = name
        self.fault_plan = fault_plan
        self.shed_queue_depth = shed_queue_depth
        # disaggregated fleet role.  "mixed" (default) serves both phases;
        # "prefill" runs chunked prefill only and hands each finished
        # prompt's KV blocks to the router's migration channel via the
        # _on_prefilled hook; "decode" is a normal engine the router
        # simply never routes fresh prompts to (adopted requests land via
        # adopt_blocks).  Roles are *policy*: a prefill replica without a
        # hook installed (standalone use) decodes its own requests.
        if role not in ("prefill", "decode", "mixed"):
            raise ValueError(f"role={role!r} must be 'prefill', 'decode' "
                             f"or 'mixed'")
        self.role = role
        # router-installed migration hook: called on the executor thread
        # with (req, keys, block_ids, gens, leaves, tokens, last) when a
        # prefill-role replica finishes a prompt
        self._on_prefilled = None
        # rid -> staged adoption payload, written by adopt_blocks on the
        # migration worker and consumed by _admit_paged on the executor
        self._adoptions: dict = {}               # guarded-by: self._adopt_lock
        self._adopt_lock = threading.Lock()
        self.fns = fns_for(cfg)
        self.max_len = max_len
        self.slots = batch_slots
        self.chunk = chunk
        if paged is None:                    # auto: families with paged fns
            paged = self.fns.init_paged_state is not None
        elif paged and self.fns.init_paged_state is None:
            raise ValueError(f"family {cfg.family!r} has no paged-KV "
                             f"support (ModelFns.init_paged_state is None)")
        self.paged = paged
        if self.role != "mixed" and not paged:
            raise ValueError("disaggregated roles need the paged KV engine "
                             "(migration moves pool blocks)")
        # speculative decoding: on iff a drafter model is given.  Greedy
        # slots then run a multi-token verify step instead of the vanilla
        # decode; non-greedy slots (and spec-off engines) are untouched.
        spec = draft_cfg is not None
        if spec:
            if not paged:
                raise ValueError("speculative decoding needs the paged KV "
                                 "engine (candidate rows are provisional "
                                 "pool blocks)")
            if spec_k < 1:
                raise ValueError(f"spec_k={spec_k} must be >= 1")
            if self.fns.verify_paged is None:
                raise ValueError(f"family {cfg.family!r} has no verify pass "
                                 f"(ModelFns.verify_paged is None)")
        self.spec_k = spec_k if spec else 0
        # worst-case provisional rows a verify step may write past a slot's
        # committed length: the pending token plus k draft candidates
        self.spec_rows = (spec_k + 1) if spec else 0
        self.block_size = block_size
        self.cache_dtype = cache_dtype
        self.prefix_sharing = prefix_sharing and paged
        # cache-seeded prefill: computation starts at the first unseeded
        # token; off = the recompute baseline (shared blocks still mapped,
        # but every prompt token re-run, its rows discarded into trash)
        self.seeded_prefill = seeded_prefill and paged
        # tiered KV: cold blocks spill to a host tier and restore through
        # the split-phase offload protocol instead of being recomputed
        if host_blocks > 0 and not paged:
            raise ValueError("KV tiering (host_blocks > 0) needs the paged "
                             "KV engine")
        if host_blocks > 0 and not self.prefix_sharing:
            raise ValueError("KV tiering keys host-resident blocks by the "
                             "prefix digests; it needs prefix_sharing=True")
        self.tiered = paged and host_blocks > 0
        if prefill_chunk is not None:
            if not paged:
                raise ValueError("prefill_chunk needs the paged KV engine")
            if prefill_chunk < block_size or prefill_chunk % block_size:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a positive "
                    f"multiple of block_size={block_size} (chunk starts "
                    f"must stay block-aligned for the pool writes)")
        self.prefill_chunk = prefill_chunk
        # prefix index: chained digest of the tokens of each full leading
        # block -> (block id, alloc generation); entries are validated
        # against the pool on lookup, so a freed-and-reused block can
        # never be shared stale
        self._prefix_index: dict[bytes, tuple[int, int]] = {}  # owned-by: executor-thread
        self.prefix_shared_total = 0  # owned-by: executor-thread; lifetime shared entries
        # slot -> in-progress chunked prefill (insertion order = service
        # order); drained by the executor under the prefill_chunk budget
        self._prefilling: dict[int, _PrefillJob] = {}  # owned-by: executor-thread
        # slot -> first output token sampled at a disaggregated handoff
        # but not yet fed through *this* pool: the adopting decode step
        # feeds it forward without re-sampling or re-delivering it
        self._adopted_feed: dict[int, int] = {}  # owned-by: executor-thread
        self._last_decode_end: float | None = None  # owned-by: executor-thread
        self._gaps_dropped = 0  # owned-by: executor-thread; decode_gaps entries trimmed
        if paged and getattr(cfg, "sliding_window", 0):
            # the paged attention paths (prefill and decode) are
            # full-causal; serving a sliding-window arch through them
            # would silently diverge from the contiguous engine
            raise ValueError(
                f"family {cfg.family!r} uses sliding_window="
                f"{cfg.sliding_window}, which the paged KV attention "
                f"paths do not mask — serve it with paged=False")
        if paged:
            worst = batch_slots * -(-(max_len + self.spec_rows)
                                    // block_size)
            self.pool = KVBlockPool(pool_blocks or worst, block_size,
                                    host_blocks=host_blocks)
            # table width covers the speculative overhang: a verify pass
            # provisionally writes up to spec_rows rows past max_len-ish
            # committed lengths before acceptance trims them back
            self.max_blocks = self.pool.blocks_for(max_len + self.spec_rows)
            self._prefix_cap = 8 * self.pool.capacity
            # host mirrors of the device block tables / lengths: growth and
            # slot retirement are numpy writes, re-injected every step
            self._tables = np.zeros((batch_slots, self.max_blocks),
                                    np.int32)   # owned-by: executor-thread
            self._lengths = np.zeros((batch_slots,),
                                     np.int32)  # owned-by: executor-thread
            if self.fns.prefill_paged is None:
                raise ValueError(f"family {cfg.family!r} has paged KV but "
                                 f"no paged prefill (ModelFns.prefill_paged"
                                 f" is None)")
            # cache-seeded chunked prefill: prompt KV written directly
            # into pool blocks; one compile per padded chunk length
            self._prefill_paged = jax.jit(
                lambda p, t, s, w, tb, qs, kl, li: self.fns.prefill_paged(
                    cfg, p, t, s, w, tb, q_start=qs, kv_len=kl,
                    last_idx=li, chunk=chunk))
        else:
            self.pool = None
        if self.tiered:
            # host tier driven as a split-phase offload device: one FIFO
            # worker (spill-before-fetch ordering for a given key is free),
            # spills fire-and-forget via submit(), fetches via submit_async
            # so _drain_tier collects them out of order between decode steps
            from repro.core.offload import KVBlockTarget, OffloadEngine
            kv_target = KVBlockTarget(self.pool.host)
            if fault_plan is not None:
                # kv.spill / kv.fetch probe sites fire on the transfer
                # worker, mapped from the payload kind by _kv_fault_hook
                kv_target.fault_hook = self._kv_fault_hook
            self._kv_io = OffloadEngine([kv_target])
            self._kv_io.__enter__()           # daemon worker; engine-lifetime
            self.pool.on_demote = self._on_demote
            self._held_digests: dict[int, bytes] = {}  # owned-by: executor-thread; bid -> key
            self._fetch_refs: dict[int, tuple] = {}    # owned-by: executor-thread; seq -> ref
            self._staged: dict[int, object] = {}       # owned-by: executor-thread; early done
            self._claimed: set[int] = set()            # owned-by: executor-thread; pre-drain
        else:
            self._kv_io = None
        if spec:
            self._drafter = _Drafter(
                draft_cfg, draft_params, slots=batch_slots, max_len=max_len,
                block_size=block_size, spec_k=spec_k, chunk=chunk,
                cache_dtype=cache_dtype)
            self._verify = jax.jit(
                lambda p, t, s, tb, qs, kl: self.fns.verify_paged(
                    cfg, p, t, s, tb, q_start=qs, kv_len=kl, chunk=chunk))
        else:
            self._drafter = None
        self._spec_on: set = set()  # owned-by: executor-thread; slots decoding speculatively
        self.scheduler = ContinuousScheduler(batch_slots, pool=self.pool,
                                             preemption=preemption,
                                             spec_rows=self.spec_rows)
        self._decode = jax.jit(
            lambda p, t, s: self.fns.decode(cfg, p, t, s, chunk=chunk))
        # jitted prefill, shape-keyed: one compile per (batch, prompt-len)
        # signature — used by the contiguous continuous path and the legacy
        # wave path (which needs a full worst-case ``max_len`` cache).
        self._prefill = jax.jit(
            lambda p, b: self.fns.prefill(cfg, p, b, max_len=max_len,
                                          chunk=chunk))
        self._merge = jax.jit(_merge_slot)
        self._prefill_shapes: set = set()  # owned-by: executor-thread; jitted signatures
        self._state = None                 # owned-by: executor-thread; decode-state pytree
        self._last: np.ndarray | None = None  # owned-by: executor-thread; (slots, V) logits
        self.totals = ServeStats()           # lifetime counters (monotonic)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        # control-plane state shared with router / traffic threads: the
        # captured executor failure and whether stop() already surfaced it
        self._ctl_lock = threading.Lock()
        self._failure: BaseException | None = None  # guarded-by: self._ctl_lock
        self._failure_raised = False                # guarded-by: self._ctl_lock
        # True once any submitted request carried a deadline_s — lets the
        # executor skip the per-step deadline sweep for deadline-free
        # workloads (monotonic bool; racing the writer only delays the
        # first sweep by one step)
        self._has_deadlines = False

    # -- model plumbing --------------------------------------------------------

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shapes seen == jit cache entries paid for."""
        return len(self._prefill_shapes)

    def _check_fits(self, req: Request) -> None:
        """Reject requests that would overrun the per-slot KV capacity —
        out-of-range cache writes clamp/drop silently under jit, corrupting
        generation instead of failing.  Paged engines additionally reject
        requests whose block count exceeds the whole pool (they could never
        be admitted, only wedge the FIFO queue)."""
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len + 1:
            raise CapacityError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new_tokens {req.max_new_tokens} exceeds KV capacity "
                f"max_len={self.max_len}")
        if self.pool is not None:
            self.pool.validate_rows(req.kv_rows + self.spec_rows, req.rid)
        if req.deadline_s is not None:
            # monotonic enable flag for the executor's deadline sweep;
            # both admission paths (blocking serve, service submit) pass
            # through here before the scheduler sees the request
            self._has_deadlines = True

    # -- fault tolerance -------------------------------------------------------

    @property
    def failure(self) -> BaseException | None:
        """The exception that killed the executor, if any (thread-safe;
        the router's health checks poll this)."""
        with self._ctl_lock:
            return self._failure

    def _fault(self, site: str, rid=None) -> str | None:
        """Fire the fault plan's probe at ``site``: returns None (no
        fault) or the action that fired — ``delay`` already slept here,
        ``raise`` already raised :class:`FaultError`, ``drop`` is the
        caller's to interpret (lost result / lost transfer).  Called from
        the executor thread and from the KV transfer worker."""
        plan = self.fault_plan
        if plan is None:
            return None
        spec = plan.fire(site, rid=rid, replica=self.name)
        if spec is None:
            return None
        with self._ctl_lock:          # probe fires on two threads
            self.totals.faults_injected += 1
        if spec.action == "delay":
            time.sleep(spec.delay_s)
            return "delay"
        if spec.action == "raise":
            raise FaultError(site, f"rid={rid}" if rid is not None else "")
        return "drop"

    def _kv_fault_hook(self, item) -> bool:
        """Transfer-worker probe (installed on the KVBlockTarget): map the
        payload kind to its site; True drops the transfer — a spill that
        never lands (the pin is released via _spill_done) or a fetch that
        reports a tier miss (the engine recomputes the block)."""
        site = "kv.spill" if item.payload[0] == "spill" else "kv.fetch"
        return self._fault(site) == "drop"

    def _finish_failed(self, req: Request, exc: BaseException) -> None:
        """Move ``req`` to its terminal FAILED state and notify."""
        with self._adopt_lock:
            # a staged-but-never-landed adoption (deadline/crash before
            # admission) must not pin its host payload forever
            staged = self._adoptions.get(req.rid)
            if staged is not None and staged.req is req:
                del self._adoptions[req.rid]
        req.state = RequestState.FAILED
        req.error = exc
        req.finished_at = time.monotonic()
        self.totals.requests_failed += 1
        if req.on_finish is not None:
            try:
                req.on_finish(req)
            except Exception:  # fault-ok: a raising completion callback must not take down the failure path reporting the failure
                pass

    def _fail_slot(self, slot: int, req: Request, exc: BaseException) -> None:
        """Poison-request isolation: one request's prefill chunk or decode
        commit raised, so *that request* fails — blocks freed, reservation
        returned, drafter mirror dropped, slot refilled next step — and
        the executor loop lives on.

        Cleanup mirrors the preemption path: popping the prefill job is
        enough for in-flight host-tier fetches (the drain's job-alive
        guard already discards commits for a dead job); only admission
        prefetches that never reached materialization need explicit
        discarding."""
        job = self._prefilling.pop(slot, None)
        if job is not None and job.pos == -1:
            for item in job.prefetch.values():
                self._discard_fetch(item)
        if self._drafter is not None:
            self._drafter.drop(slot)
            self._spec_on.discard(slot)
        self.scheduler.release(slot)       # blocks + reservation tail back
        if self.paged:
            self._retire_slot(slot)
        self._finish_failed(req, exc)
        self.scheduler.notify_capacity()   # a slot just opened

    def _record_crash(self, exc: BaseException) -> None:
        """Executor crash capture (runs on the dying executor thread): a
        non-request fault escaped :meth:`_step`.  Capture it so it
        surfaces through :attr:`failure` / :meth:`stop` instead of a
        join-timeout, poison the scheduler against late submits, and
        fail every request this executor will now never serve."""
        with self._ctl_lock:
            if self._failure is None:
                self._failure = exc
        self.scheduler.poison(exc)
        failed = self.scheduler.drain_queue()
        for slot, req in self.scheduler.active():
            try:
                self._fail_slot(slot, req, exc)
            except Exception:  # fault-ok: crash-path cleanup is best-effort — the pool may be mid-mutation from the very fault being handled
                self._finish_failed(req, exc)
        for req in failed:
            self._finish_failed(req, exc)

    def _raise_failure_once(self) -> None:
        """Surface a captured executor crash exactly once (stop() calls
        this; a second stop() is then silent — idempotent teardown)."""
        with self._ctl_lock:
            failure = self._failure
            raised = self._failure_raised
            self._failure_raised = True
        if failure is not None and not raised:
            raise ExecutorCrash(
                "executor thread died mid-serve") from failure

    def _sweep_deadlines(self) -> None:
        """Fail queued and active requests whose hard deadline elapsed —
        decoding them further would deliver tokens the caller has already
        abandoned.  Skipped entirely for deadline-free workloads."""
        if not self._has_deadlines:
            return
        now = time.monotonic()
        for req in self.scheduler.expire_deadlines(now):
            self._finish_failed(
                req, DeadlineExceeded(
                    f"request {req.rid}: deadline {req.deadline_s}s "
                    f"elapsed while queued"))
        for slot, req in self.scheduler.active():
            if req.deadline_elapsed(now):
                self._fail_slot(
                    slot, req, DeadlineExceeded(
                        f"request {req.rid}: deadline {req.deadline_s}s "
                        f"elapsed after {len(req.output)} tokens"))

    def _batch_for(self, prompts: np.ndarray) -> dict:
        """prompts: (W, S) -> model batch dict (positions/frames as needed)."""
        W, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.m_rope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (W, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, W, S))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (W, self.cfg.encdec.num_encoder_frames, self.cfg.d_model),
                jnp.float32)
        return batch

    def _bucket_len(self, n: int) -> int:
        """Smallest power-of-two multiple of block_size holding ``n``."""
        b = self.block_size
        while b < n:
            b *= 2
        return b

    def _prefill_one(self, req: Request):
        """Dense prefill of one prompt -> ((V,) logits, batch-1 state) —
        the contiguous-KV path (paged engines prefill straight into pool
        blocks via :meth:`_advance_prefill`).

        Uses ``req.prefill_tokens`` — prompt plus any tokens generated
        before a preemption — so an evicted request resumes recompute-style
        with its history re-prefilled."""
        prompt = req.prefill_tokens
        self._prefill_shapes.add((1, len(prompt)))
        batch = self._batch_for(prompt[None])
        last, state = self._prefill(self.params, batch)
        return np.asarray(last[0]), state

    def _init_state(self):
        """Batched decode-state template covering all slots."""
        if self.paged:
            return self.fns.init_paged_state(
                self.cfg, self.pool.total_blocks, self.block_size,
                self.slots, self.max_blocks, self.cache_dtype)
        return self.fns.init_decode_state(self.cfg, self.slots, self.max_len)

    # -- executor step ---------------------------------------------------------

    def _sample_active(self, active: list[tuple[int, Request]]) -> dict[int, int]:
        """Vectorized sampling: group slots by sampler batch_key, one
        `sample` call per group (one argmax for the whole batch when all
        slots are greedy)."""
        groups: dict = {}
        for slot, req in active:
            groups.setdefault(req.sampler.batch_key, []).append((slot, req))
        toks: dict[int, int] = {}
        for members in groups.values():
            rows = np.array([s for s, _ in members])
            out = members[0][1].sampler.sample(self._last[rows])
            for (slot, _), tok in zip(members, out):
                toks[slot] = int(tok)
        return toks

    def _prefix_keys(self, tokens: np.ndarray) -> list[bytes]:
        """Engine-local view of :func:`prefix_digests` at this engine's
        block size (the router computes the same digests fleet-side)."""
        return prefix_digests(tokens, self.block_size)

    def _lookup_prefix(self, keys: list[bytes]) -> list[int]:
        """Longest run of full leading blocks already resident in the pool
        for this token prefix.  Dead index entries (block freed, or freed
        and re-allocated — the generation tag catches both) are pruned on
        the way."""
        shared: list[int] = []
        for key in keys:
            ent = self._prefix_index.get(key)
            if ent is None:
                break
            bid, gen = ent
            if not self.pool.block_live(bid, gen):
                del self._prefix_index[key]
                break
            shared.append(bid)
        if self.tiered and shared:
            # a hit refreshes demotion LRU: blocks just seeded from are
            # the worst possible eviction victims
            self.pool.touch(shared)
        return shared

    def _register_prefix(self, keys: list[bytes], req: Request) -> None:
        """Publish the request's own *full* prompt blocks under their token
        prefix so later requests with the same leading tokens share (and,
        seeded, skip recomputing) them.  Called only once the blocks'
        rows are actually in the pool — a mid-prefill publication would
        let a concurrent admission seed from unwritten blocks.

        A live publication wins, but a dead entry (block freed or reused
        since) is overwritten — otherwise one round of pool churn would
        leave dead tombstones blocking re-publication for that prefix."""
        for j in range(req.shared_blocks, len(keys)):
            ent = self._prefix_index.get(keys[j])
            if ent is not None and self.pool.block_live(*ent):
                continue
            bid = req.block_ids[j]
            self._prefix_index[keys[j]] = (bid, self.pool.generation(bid))
            if self.tiered and bid not in self._held_digests:
                # the index itself holds the block: when its requests all
                # leave it turns *demotable* (spill-then-free on demand)
                # instead of vanishing into the free list
                self.pool.hold(bid)
                self._held_digests[bid] = keys[j]
        if self.tiered:
            # tiered mode un-caps the index by recency: live entries are
            # bounded by pool capacity (each holds a distinct block) and
            # dead ones are just tombstones — prune those, keep the rest
            if len(self._prefix_index) > self._prefix_cap:
                self._prefix_index = {
                    k: e for k, e in self._prefix_index.items()
                    if self.pool.block_live(*e)}
            return
        if len(self._prefix_index) > self._prefix_cap:
            # two-phase trim: stale-generation entries go first, and only
            # if that is not enough are *live* entries capped —
            # oldest-published first (dict order) — so hot shared prefixes
            # are never silently un-published while dead tombstones
            # survive the sweep
            live = {k: e for k, e in self._prefix_index.items()
                    if self.pool.block_live(*e)}
            for k in list(live)[:max(0, len(live) - self._prefix_cap)]:
                del live[k]
            self._prefix_index = live

    # -- KV tiering: host-offloaded blocks over the split-phase protocol ------

    def _read_block_slices(self, bid: int) -> dict:
        """Immutable per-leaf device slices of one pool block, captured on
        the executor thread *before* the block id can be reused: jax
        arrays are immutable, so a later functional update to the pool
        leaves this capture reading the pre-update buffer — the offload
        worker can materialize it to host numpy at its leisure."""
        leaves = {}
        for name in ("k", "v", "k_scale", "v_scale"):
            arr = getattr(self._state, name, None)
            if arr is not None:
                leaves[name] = arr[:, bid]
        return leaves

    def _write_block(self, bid: int, payload: dict) -> None:
        """Restore one fetched block's rows into pool block ``bid`` (a
        functional update; the in-flight decode step keeps reading the
        old buffers, exactly like a prefill chunk write)."""
        repl = {}
        for name, host in payload.items():
            arr = getattr(self._state, name)
            repl[name] = arr.at[:, bid].set(
                jnp.asarray(host).astype(arr.dtype))
        self._state = self._state._replace(**repl)

    def _write_blocks(self, bids: list[int], payloads: list[dict]) -> None:
        """Batched :meth:`_write_block`: land ``payloads[i]`` into pool
        block ``bids[i]`` with one functional scatter per state leaf.
        An adopted long prompt arrives as dozens of blocks; writing them
        one dispatch at a time would stall the decode loop for the whole
        batch."""
        if not bids:
            return
        # pad to a pow-2 bucket: the scatter compiles once per distinct
        # index-count shape, so unbucketed writes would pay a fresh
        # compile (hundreds of ms — a decode-cadence outlier) for every
        # new adoption size; bucketing caps the shape set at
        # log2(blocks) entries, all warmable.  The pad rows repeat the
        # last (id, payload) pair — duplicate scatter indices carrying
        # identical values land deterministically.
        n = len(bids)
        cap = 1
        while cap < n:
            cap <<= 1
        bids = bids + [bids[-1]] * (cap - n)
        payloads = payloads + [payloads[-1]] * (cap - n)
        idx = jnp.asarray(bids, dtype=jnp.int32)
        repl = {}
        for name in payloads[0]:
            arr = getattr(self._state, name)
            stacked = np.stack([np.asarray(p[name]) for p in payloads],
                               axis=1)
            repl[name] = arr.at[:, idx].set(
                jnp.asarray(stacked).astype(arr.dtype))
        self._state = self._state._replace(**repl)

    def _spill_block(self, bid: int, key: bytes) -> bool:
        """Queue one block's device->host copy under ``key`` unless the
        tier already holds (or is receiving) it; returns True if queued.
        The copy itself runs on the offload worker, overlapped with
        decode steps — only the O(1) slice capture happens here."""
        host = self.pool.host
        if key in host:
            return False
        host.begin_store(key)           # pin: tier eviction skips pendings
        leaves = self._read_block_slices(bid)
        self._kv_io.submit(("spill", key, leaves),
                           on_done=lambda item, key=key:
                           self._spill_done(key, item))
        self.totals.kv_spills += 1
        self.totals.spill_bytes += sum(int(v.nbytes)
                                       for v in leaves.values())
        return True

    def _spill_done(self, key: bytes, item) -> None:
        """Spill completion hook (transfer-worker thread): a dropped or
        failed spill leaves a pinned pending placeholder nothing will
        ever fill — release it, so the tier does not leak and a later
        fetch of the key cleanly misses into recompute."""
        if item.result is None or isinstance(item.result, WorkError):
            self.pool.host.drop(key)

    # assumes-lock: KVBlockPool._lock
    def _on_demote(self, ids: list[int]) -> None:
        """Pool demotion hook (runs under the pool lock — must not
        re-enter the pool): an idle index-held block is about to return
        to the free list, so its content spills to the host tier first.
        The slice capture above makes the free race-safe."""
        for bid in ids:
            key = self._held_digests.pop(bid, None)
            if key is not None:
                self._spill_block(bid, key)

    def _spill_victim(self, req: Request) -> None:
        """Preemption demote-on-evict: the victim's freed history blocks
        (prompt + generated, folded) spill keyed by the same chained
        digests re-admission will look up — resume then *restores* the
        history instead of recomputing it.  Runs in the drain_preempted
        handler, before any post-eviction prefill can write the freed
        ids, and the capture keeps even that ordering a non-issue."""
        ids, req.evicted_block_ids = req.evicted_block_ids, []
        if not self.tiered or not ids:
            return
        keys = self._prefix_keys(req.prefill_tokens)
        for j in range(min(len(keys), len(ids))):
            ent = self._prefix_index.get(keys[j])
            if ent is not None and self.pool.block_live(*ent):
                continue                # still device-resident via the index
            self._spill_block(ids[j], keys[j])

    def _seed_pos(self, job: _PrefillJob) -> int:
        """First unseeded row once fetches settle: the device-shared run
        plus the contiguous restored run after it (a failed fetch caps
        the run; recompute overwrites the own blocks past it)."""
        if not self.seeded_prefill:
            return 0
        j = job.seed_base
        while j in job.fetched_ok:
            j += 1
        return j * self.block_size

    def _drain_tier(self, timeout: float | None = 0.0) -> None:
        """Collect completed host-tier fetches and commit them into their
        jobs' pool blocks.  Runs on the executor thread between decode
        steps (and blocking briefly when a prefill has nothing else to
        do).  A commit is guarded three ways: the job must still be its
        slot's live prefill (not preempted since), the target block must
        still be this allocation (generation tag — the spill->free->
        realloc->fetch race), and the payload non-None (the tier may
        have evicted the key after the prefetch probe)."""
        if not self.tiered:
            return
        while True:
            item = self._kv_io.next_done(timeout=timeout)
            if item is None:
                return
            timeout = 0.0                # only block for the first item
            if item.seq in self._claimed:
                self._claimed.discard(item.seq)
                continue
            ref = self._fetch_refs.pop(item.seq, None)
            if ref is None:
                # prefetch finished before its job materialized blocks:
                # park it — _materialize_blocks consumes it from here
                self._staged[item.seq] = item
                continue
            job, j, bid, gen = ref
            job.pending_n -= 1
            alive = self._prefilling.get(job.slot) is job
            result = item.result
            if isinstance(result, WorkError):  # failed transfer = tier miss
                result = None
            if (result is not None and alive
                    and self.pool.block_live(bid, gen)):
                self._write_block(bid, result)
                job.fetched_ok.add(j)
                self.totals.kv_fetches += 1
                self.totals.prefix_hits_host += 1
            if alive and job.pending_n == 0 and job.pos == -2:
                job.pos = self._seed_pos(job)

    def _discard_fetch(self, item) -> None:
        """Drop an unused fetch item (prefetch past the seed window, or a
        dead job's leftovers) without leaking drain-side state."""
        if item.seq in self._staged:
            del self._staged[item.seq]   # already popped from the done-q
        else:
            self._claimed.add(item.seq)  # done-q will deliver; drain drops

    def drain_tier_io(self, timeout: float = 10.0) -> None:
        """Quiesce the host-tier transfer engine: block until every
        in-flight spill and fetch has landed (or been dropped) and the
        drain-side staging state is empty.  Chaos tests call this after a
        serve — or after a crash, when nobody else will ever drain — so
        the leak check never misreads a transient ``_PENDING`` pin or a
        parked fetch as a leak."""
        if not self.tiered:
            return
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self._drain_tier(timeout=0.01)
            for seq in list(self._staged):       # orphans with no live job
                self._staged.pop(seq)
            if (not self._fetch_refs and not self._staged
                    and self.pool.host.pending_count == 0):
                return
        raise TimeoutError("host-tier IO did not quiesce within "
                           f"{timeout}s: {self.pool.leak_report()}")

    def _admit_paged(self, slot: int, req: Request) -> None:
        """Queue an admitted request's cache-seeded chunked prefill
        (block materialization is deferred to its first chunk — see
        :meth:`_materialize_blocks`).

        The decode-state table row stays at the trash block until the
        prefill completes: the in-flight batched decode keeps writing
        this slot's (discarded) row, and must not corrupt half-filled
        prompt blocks.

        A request whose KV arrived by migration skips prefill entirely:
        its staged adoption payload lands here instead.  A *preempted*
        adopted request finds its payload already consumed and falls
        through to the normal recompute path — roles are placement
        policy, not an engine capability split."""
        with self._adopt_lock:
            adoption = self._adoptions.get(req.rid)
            if adoption is not None and adoption.req is req:
                del self._adoptions[req.rid]
            else:
                adoption = None
        if adoption is not None:
            self._adopt_slot(slot, req, adoption)
            return
        toks = req.prefill_tokens
        P = len(toks)
        nb = self.pool.blocks_for(P)
        keys = self._prefix_keys(toks) if self.prefix_sharing else []
        self._tables[slot] = 0
        self._lengths[slot] = 0
        job = _PrefillJob(req=req, tokens=toks, nb=nb, keys=keys, slot=slot)
        self._prefilling[slot] = job
        self.totals.prefill_tokens_total += P
        if self.tiered and self.seeded_prefill:
            # prefetch-at-admission: fetches for the host-resident run
            # past the device-resident run start moving now, overlapped
            # with everything between admission and this job's first
            # chunk (materialization claims or re-probes them)
            host = self.pool.host
            ndev = len(self._lookup_prefix(keys))
            for key in keys[ndev:]:
                if key not in host:
                    break
                job.prefetch[key] = self._kv_io.submit_async(("fetch", key))

    def _materialize_blocks(self, job: _PrefillJob) -> None:
        """First-chunk block materialization: map shared prefix blocks
        (seeding past them when enabled) and allocate the tail from the
        reservation the scheduler took at admission.  Deferred to here —
        not admission — so a job admitted in the same batch as an
        identical-prefix predecessor still finds the predecessor's
        published blocks (jobs advance oldest-first, so the predecessor
        has completed by the time this one starts)."""
        req = job.req
        P = len(job.tokens)
        bs = self.block_size
        shared = self._lookup_prefix(job.keys)[:(P - 1) // bs]
        ns = len(shared)
        if ns:
            self.pool.share(shared)
            self.pool.unreserve(ns)          # shared blocks need no copy
            self.prefix_shared_total += ns
        own = self.pool.alloc_reserved(job.nb - ns)
        req.block_ids = shared + own
        req.shared_blocks = ns
        req.blocks_reserved -= job.nb       # remaining = decode-growth tail
        self.totals.prefix_lookups += len(job.keys)
        job.seed_base = ns
        if not (self.tiered and self.seeded_prefill):
            job.pos = ns * bs if self.seeded_prefill else 0
            return
        # host-restorable run: own blocks past the device-shared run whose
        # content the host tier holds — claim the admission prefetches (or
        # probe late for keys that demoted since), committing into the
        # just-allocated blocks as each fetch lands
        host = self.pool.host
        used: set[int] = set()
        for j in range(ns, (P - 1) // bs):
            key = job.keys[j]
            item = job.prefetch.get(key)
            if item is None:
                if key not in host:
                    break
                item = self._kv_io.submit_async(("fetch", key))
                job.prefetch[key] = item
            used.add(item.seq)
            bid, gen = req.block_ids[j], self.pool.generation(req.block_ids[j])
            if item.done.is_set():           # landed before materialization
                result = item.result
                if isinstance(result, WorkError):
                    result = None            # failed transfer = tier miss
                if result is None:
                    self._discard_fetch(item)
                    break                    # evicted since the probe: the
                                             # seed run caps here, recompute
                                             # overwrites the blocks past it
                self._write_block(bid, result)
                job.fetched_ok.add(j)
                self.totals.kv_fetches += 1
                self.totals.prefix_hits_host += 1
                self._discard_fetch(item)    # retire its drain-side state
            else:
                self._fetch_refs[item.seq] = (job, j, bid, gen)
                job.pending_n += 1
        for item in job.prefetch.values():   # prefetches past the run/cap
            if item.seq not in used and item.seq not in self._fetch_refs:
                self._discard_fetch(item)
        job.pos = -2 if job.pending_n else self._seed_pos(job)

    def _advance_prefill(self, slot: int, budget: int | None = None) -> int:
        """Run one chunk of a slot's prefill straight into its pool blocks;
        returns the number of real prompt tokens computed.

        Each call processes up to ``prefill_chunk`` tokens — and no more
        than ``budget`` (floored to a block multiple), so a step never
        overspends its prefill budget across several jobs — right-padded
        to a power-of-two bucket capped at the chunk; the jitted
        signature is keyed by the padded chunk length, not the prompt
        length (the whole remaining prompt when un-chunked).  Rows that
        must not land anywhere (bucket padding past the prompt, and the
        recompute-baseline's shared-prefix rows) write to the trash
        block.  On the final chunk the slot's decode table/length go live
        and the prompt's full blocks are published to the prefix index.
        """
        job = self._prefilling[slot]
        req = job.req
        if self._fault("engine.prefill", rid=req.rid) == "drop":
            raise FaultError("engine.prefill",
                             f"dropped prefill chunk of {req.rid}")
        if job.pos == -1:
            self._materialize_blocks(job)
        if job.pos == -2:
            # host-tier fetches still inbound: try a non-blocking drain,
            # then skip this slot for the step (like a mid-prefill slot)
            # rather than stall the batch on the transfer
            self._drain_tier(timeout=0.0)
            if job.pos == -2:
                return 0
        P = len(job.tokens)
        start = job.pos
        remaining = P - start
        bucket = self._bucket_len(remaining)
        bs = self.block_size
        cap = self.prefill_chunk
        if cap is not None and budget is not None and budget < cap:
            # spend only a power-of-two multiple of block_size of the
            # leftover budget: an arbitrary block-multiple width would be
            # a never-warmed jit signature compiling on the hot path
            cap = bs
            while cap * 2 <= budget:
                cap *= 2
        Cpad = min(cap, bucket) if cap else bucket
        real = min(remaining, Cpad)
        b0 = start // bs
        chunk_toks = np.zeros((1, Cpad), np.int32)
        chunk_toks[0, :real] = job.tokens[start:start + real]
        wids = np.zeros((Cpad // bs,), np.int32)
        for j in range(Cpad // bs):
            lb = b0 + j                      # logical block of this write
            if req.shared_blocks <= lb < job.nb:
                wids[j] = req.block_ids[lb]
        # read table sliced to the blocks this chunk can actually see
        # (rounded up to a power of two): the attention gather scales
        # with rows seeded-so-far, not the slot's worst-case table width,
        # and the compile cache is keyed by (chunk, seeded) shape
        mb_need = -(-(start + real) // bs)
        mb_eff = 1
        while mb_eff < mb_need:
            mb_eff *= 2
        mb_eff = min(mb_eff, self.max_blocks)
        tbl = np.zeros((1, mb_eff), np.int32)
        nb_vis = min(job.nb, mb_eff)
        tbl[0, :nb_vis] = req.block_ids[:nb_vis]
        self._prefill_shapes.add((1, Cpad, mb_eff))
        last, self._state = self._prefill_paged(
            self.params, jnp.asarray(chunk_toks), self._state,
            jnp.asarray(wids), jnp.asarray(tbl),
            jnp.asarray([start], jnp.int32),
            jnp.asarray([start + real], jnp.int32),
            jnp.int32(real - 1))
        if self.role == "prefill":
            # full-budget chunks dispatch back-to-back, and on a shared
            # backend (co-located replicas in tests and benches) an
            # unforced run piles tens of ms of queued compute that a
            # decode replica's next op then waits behind — force each
            # chunk so the convoy never forms.  A dedicated-device
            # prefill replica loses nothing: its chunks are serially
            # dependent through the KV state anyway.
            jax.block_until_ready(last)
        self.totals.prefill_tokens_computed += real
        job.pos = start + real
        if job.pos == P:                     # logits of the last real token
            del self._prefilling[slot]
            self._tables[slot] = 0
            if self.role == "prefill" and self._on_prefilled is not None:
                # disaggregated fleet: this replica's work ends at the
                # last prompt token — hand the blocks to the router's
                # migration channel instead of entering decode
                self._handoff(slot, job, req, np.asarray(last[0]))
                return real
            if slot in self._spec_on:
                # speculative slots never join the batched vanilla decode:
                # their batched-state table row stays at trash (the decode
                # step's write for this slot must keep landing nowhere) and
                # the verify pass addresses the real blocks through its own
                # per-step table argument.  Seed the drafter's mirror now —
                # after a preemption resume ``job.tokens`` carries the
                # folded committed output, so the drafter re-prefills the
                # same history the target just did.
                self._lengths[slot] = 0
                self._drafter.seed(
                    slot, job.tokens,
                    len(req.prompt) + req.max_new_tokens + self.spec_k)
            else:
                self._tables[slot, :job.nb] = req.block_ids
                self._lengths[slot] = P
            self._set_last(slot, np.asarray(last[0]))
            if self.prefix_sharing:
                self._register_prefix(job.keys, req)
            req.state = RequestState.DECODE
            # a PREFILL slot just became DECODE — i.e. preemptible — so a
            # queue head blocked on pool pressure is worth re-checking
            self.scheduler.notify_capacity()
        return real

    def _handoff(self, slot: int, job: _PrefillJob, req: Request,
                 last1: np.ndarray) -> None:
        """Disaggregated prefill completion (executor thread): export-pin
        the prompt's blocks, capture their device slices, release the
        slot, and fire the router's migration hook.

        Ordering is what makes the in-flight payload safe against
        free/realloc: :meth:`KVBlockPool.export_blocks` adds a holder per
        block *before* ``release()`` drops the request's holders, so the
        ids stay allocated (and their generations frozen) until the
        router's completion hook frees the export — and the slices
        captured here are immutable jax arrays, so even post-release
        writes to the pool leave them reading the pre-release buffers
        (the same trick the tiered spill path relies on)."""
        # Real disaggregation returns the first token from the prefill
        # node: the final-chunk logits are already in hand, so sample and
        # deliver it here — migration latency leaves the TTFT path
        # entirely.  The adopting replica feeds this token forward
        # without re-sampling it (bit-identical: same logits, and the
        # sampler's stream advances exactly once).
        tok = int(req.sampler.sample(last1[None])[0])
        req.output.append(tok)
        if req.first_token_at is None:
            req.first_token_at = time.monotonic()
        self.totals.tokens += 1
        if len(req.output) >= req.max_new_tokens:
            # single-token request: DONE at handoff — nothing to migrate
            if self.prefix_sharing:
                self._register_prefix(job.keys, req)
            self._spec_on.discard(slot)
            req.state = RequestState.DONE
            req.finished_at = time.monotonic()
            self.scheduler.release(slot)
            self._retire_slot(slot)
            self.scheduler.notify_capacity()
            if req.on_finish is not None:
                req.on_finish(req)
            return
        ids = list(req.block_ids)
        gens = self.pool.export_blocks(ids)
        leaves = [self._read_block_slices(b) for b in ids]
        if self.prefix_sharing:
            # publish locally too: a later prompt sharing this prefix
            # prefills cache-seeded on this replica
            self._register_prefix(job.keys, req)
        self._spec_on.discard(slot)   # drafter was never seeded: the slot
        #                               retires before its decode begins
        req.state = RequestState.PREFILLED
        self.scheduler.release(slot)  # request holders drop; exports stay
        self._retire_slot(slot)
        self.scheduler.notify_capacity()   # slot + blocks just came back
        self._on_prefilled(req, list(job.keys), ids, gens, leaves,
                           np.asarray(job.tokens), last1)

    def _adopt_slot(self, slot: int, req: Request,
                    adoption: _Adoption) -> None:
        """Land a migrated prefill straight into this pool (executor
        thread): allocate blocks from the admission reservation, write
        the payload rows functionally (the in-flight decode step keeps
        reading the old buffers, exactly like a prefill chunk write),
        and enter DECODE *after* the handoff-sampled first token — the
        next decode step feeds that token forward instead of sampling,
        so greedy outputs stay bit-identical to a local prefill and no
        sampler stream advances twice."""
        tokens = adoption.tokens
        P = len(tokens)
        nb = self.pool.blocks_for(P)
        own = self.pool.alloc_reserved(nb)
        req.block_ids = own
        req.shared_blocks = 0
        req.blocks_reserved -= nb       # remaining = decode-growth tail
        # generation-safe: `own` was alloc_reserved just above — private
        # refcount-1 blocks no other slot can reference, so no generation
        # check is needed before writing
        self._write_blocks(own, adoption.blocks)
        self._tables[slot] = 0
        if slot in self._spec_on:
            # same contract as prefill completion: speculative slots stay
            # off the batched vanilla decode; the drafter re-prefills the
            # migrated history through its own mirror (drafter compute,
            # not target prompt recompute)
            self._lengths[slot] = 0
            self._drafter.seed(slot, tokens,
                               len(req.prompt) + req.max_new_tokens
                               + self.spec_k)
            # the verify invariant wants ``_last`` = distribution after
            # the committed stream with every committed row written; the
            # handoff-sampled token has neither, so hand it back to the
            # verify pass as its pending ``t_0`` (no re-sample — a
            # stochastic sampler's stream must not advance twice) and
            # pre-compensate the commit's recount of a token the handoff
            # already delivered
            self._adopted_feed[slot] = req.output.pop()
            self.totals.tokens -= 1
        else:
            self._tables[slot, :nb] = own
            self._lengths[slot] = P
            # the handoff already sampled and delivered ``output[-1]``;
            # the next decode step feeds it forward (writing KV row P and
            # producing next-token logits) without re-sampling it
            self._adopted_feed[slot] = req.output[-1]
        self._set_last(slot, adoption.last)
        if self.prefix_sharing:
            self._register_prefix(adoption.keys, req)
        self.totals.kv_migrations += 1
        self.totals.migrated_blocks += nb
        # the whole prompt arrives precomputed: total rises, computed does
        # not — prefill_compute_frac is the zero-recompute evidence
        self.totals.prefill_tokens_total += P
        req.state = RequestState.DECODE
        self.scheduler.notify_capacity()

    def _set_last(self, slot: int, last1: np.ndarray) -> None:
        """Store one slot's next-token logits (lazy-allocating the batch
        buffer, and un-aliasing it when it is a read-only view of a jax
        buffer from the last decode step)."""
        if self._last is None:
            self._last = np.zeros((self.slots, last1.shape[-1]),
                                  last1.dtype)
        if not self._last.flags.writeable:
            self._last = self._last.copy()
        self._last[slot] = last1

    def _retire_slot(self, slot: int) -> None:
        """Point a finished slot's table at the trash block before its
        freed blocks can be reused — the batched decode still writes a
        (discarded) row for this slot every step."""
        self._tables[slot] = 0
        self._lengths[slot] = 0
        # a handoff-sampled token pending for a slot that dies before its
        # feed step must not leak into the slot's next occupant
        self._adopted_feed.pop(slot, None)

    def _grow_paged(self, still: list[tuple[int, Request]]) -> None:
        """Allocate the next block for any request whose write position
        crossed a block boundary, then re-inject the host-side tables and
        lengths into the decode state."""
        bs = self.block_size
        for slot, req in still:
            pos = len(req.prompt) + len(req.output) - 1   # row written next
            if pos >= len(req.block_ids) * bs:
                nb = len(req.block_ids)
                req.block_ids.extend(self.pool.alloc_reserved(1))
                req.blocks_reserved -= 1
                self._tables[slot, nb] = req.block_ids[-1]
            self._lengths[slot] = pos
        self._state = self._state._replace(
            block_tables=jnp.asarray(self._tables),
            length=jnp.asarray(self._lengths))

    def _step(self) -> bool:
        """One executor iteration: refill free slots, spend the chunked
        prefill budget, sample one token per decoding slot (vectorized),
        advance the batched decode step.  Returns False when there was no
        work."""
        # a raise here is a *replica* fault, not a request fault: it
        # escapes _step, kills the executor, and exercises the crash
        # capture path (_record_crash / failure / stop)
        self._fault("replica.executor")
        self._sweep_deadlines()
        admitted = self.scheduler.admit()
        if self.paged:
            # trash the tables of any slots admit() preempted *before*
            # prefilling new prompts into the freed blocks: the victim slot
            # keeps writing its (discarded) decode row to the trash block
            for slot, victim in self.scheduler.drain_preempted():
                self._retire_slot(slot)
                self._spill_victim(victim)
                self._prefilling.pop(slot, None)
                if self._drafter is not None:
                    # the victim's drafter mirror dies with its target KV;
                    # a resume re-seeds it from the folded committed output
                    self._drafter.drop(slot)
                    self._spec_on.discard(slot)
        for slot, req in admitted:
            self.totals.prefills += 1
            if self._state is None:
                self._state = self._init_state()
            if self._drafter is not None:
                # speculation is per-slot: only greedy samplers have the
                # argmax-chain acceptance that keeps outputs bit-identical
                if req.sampler.batch_key == "greedy":
                    self._spec_on.add(slot)
                else:
                    self._spec_on.discard(slot)
            try:
                if self.paged:
                    self._admit_paged(slot, req)
                    if self.prefill_chunk is None:
                        # un-chunked: finish this prompt before admitting
                        # the next, so its published prefix blocks are
                        # sharable (and seedable) by the very next
                        # admission; a zero advance means the job is
                        # waiting on host-tier fetches — block briefly on
                        # the drain, there is nothing else to overlap
                        # them with here
                        while slot in self._prefilling:
                            if self._advance_prefill(slot) == 0:
                                self._drain_tier(timeout=0.005)
                else:
                    if self._fault("engine.prefill", rid=req.rid) == "drop":
                        raise FaultError("engine.prefill",
                                         f"dropped prefill of {req.rid}")
                    last1, state1 = self._prefill_one(req)
                    self.totals.prefill_tokens_total += \
                        len(req.prefill_tokens)
                    self.totals.prefill_tokens_computed += \
                        len(req.prefill_tokens)
                    self._state = self._merge(self._state, state1,
                                              jnp.int32(slot))
                    self._set_last(slot, last1)
                    req.state = RequestState.DECODE
            except Exception as e:  # noqa: BLE001 — poison isolation:
                # one request's raising prefill fails that request, not
                # the executor (crash faults escape one level up)
                self._fail_slot(slot, req, e)

        if self._prefilling:
            # chunked mode: spend at most prefill_chunk prompt tokens per
            # executor step, oldest admission first, then fall through to
            # the decode step — a long prompt prefills interleaved with
            # decodes instead of stalling them for its whole length.  The
            # remaining budget caps each chunk, so finishing one job and
            # starting the next can never overspend the step.  A
            # prefill-role replica has no decode slots to protect: it
            # keeps the chunk-sized jit buckets but runs them
            # back-to-back at full budget instead of one per step.
            self._drain_tier(timeout=0.0)    # commit landed fetches first
            budget = (self.prefill_chunk if self.role != "prefill"
                      else (1 << 30))
            while budget >= self.block_size:
                # oldest admission first, skipping slots whose blocks are
                # still inbound from the host tier (skip-while-inbound:
                # the fetch overlaps the chunks and decode steps below)
                job = next((j for j in self._prefilling.values()
                            if j.pos != -2), None)
                if job is None:
                    break
                try:
                    budget -= self._advance_prefill(job.slot, budget)
                except Exception as e:  # noqa: BLE001 — poison isolation
                    self._fail_slot(job.slot, job.req, e)

        active = self.scheduler.decoding()
        if not active:
            # no decodes to stall — a prefill-only period is not a decode
            # gap, so the cadence anchor resets either way
            self._last_decode_end = None
            if (self._prefilling
                    and all(j.pos == -2
                            for j in self._prefilling.values())):
                # every job is waiting on inbound blocks and there is no
                # decode to overlap with: block briefly on the drain
                # instead of spinning the executor
                self._drain_tier(timeout=0.005)
            return bool(self._prefilling)

        spec = ([(s, r) for s, r in active if s in self._spec_on]
                if self._drafter is not None else [])
        spec_slots = {s for s, _ in spec}
        if spec:
            self._verify_step(spec)
        active = [(s, r) for s, r in active if s not in spec_slots]
        if not active:
            return True

        toks = self._sample_active(
            [(s, r) for s, r in active if s not in self._adopted_feed])
        now = time.monotonic()
        feed = np.zeros((self.slots,), np.int32)
        for slot, req in active:
            pend = self._adopted_feed.pop(slot, None)
            tok = toks[slot] if pend is None else pend
            try:
                if self._fault("engine.decode", rid=req.rid) == "drop":
                    raise FaultError("engine.decode",
                                     f"dropped decode commit of {req.rid}")
            except Exception as e:  # noqa: BLE001 — poison isolation: the
                # failed slot leaves `feed` at 0 against a trashed table,
                # exactly like a retired speculative slot
                self._fail_slot(slot, req, e)
                continue
            feed[slot] = tok
            if pend is not None:
                # adopted slot: this token was sampled and delivered at
                # the prefill replica's handoff — feed it forward, but do
                # not deliver it twice (it cannot be the request's final
                # token either: single-token requests finish at handoff)
                continue
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.append(tok)
            self.totals.tokens += 1
            if len(req.output) >= req.max_new_tokens:
                req.state = RequestState.DONE
                req.finished_at = time.monotonic()
                self.scheduler.release(slot)   # returns blocks to the pool
                if self.paged:
                    self._retire_slot(slot)
                if req.on_finish is not None:
                    req.on_finish(req)

        still = [(s, r) for s, r in self.scheduler.decoding()
                 if s not in self._spec_on]
        if still:        # someone needs next-token logits
            if self.paged:
                self._grow_paged(still)
            last, self._state = self._decode(
                self.params, jnp.asarray(feed)[:, None], self._state)
            last = np.asarray(last)
            if self._spec_on:
                # speculative slots fed 0 against trash tables: their rows
                # of this batched decode are garbage, and their real next-
                # token logits (set by the verify pass) must survive it
                keep = sorted(self._spec_on)
                last = last.copy()
                last[keep] = self._last[keep]
            self._last = last
            self._note_decode_cadence()
            self.totals.decode_steps += 1
            self.totals.occupancy_sum += len(still) / self.slots
        else:
            self._last_decode_end = None     # cadence broken, not stalled
        return True

    def _note_decode_cadence(self) -> None:
        """Record the wall-clock gap since the previous decode-cadence step
        (vanilla decode or speculative verify) — chunked-prefill stalls
        surface here as ``decode_gaps`` outliers."""
        now = time.monotonic()
        if self._last_decode_end is not None:
            gaps = self.totals.decode_gaps
            gaps.append(now - self._last_decode_end)
            if len(gaps) > 65536:            # bound the lifetime list: a
                drop = len(gaps) // 2        # service-mode engine decodes
                del gaps[:drop]              # indefinitely
                self._gaps_dropped += drop
        self._last_decode_end = now

    def _verify_step(self, spec: list[tuple[int, Request]]) -> None:
        """One speculative draft-and-verify round for every speculative
        decoding slot: propose ``k`` drafter tokens per slot, score the
        pending greedy token plus all drafts in one batched target pass,
        commit the longest prefix of drafts matching the target's argmax
        chain, and roll back the rejected tail's provisional blocks.

        Engine invariant (identical to vanilla decode): entering with
        ``n`` committed output tokens, KV rows ``0 .. P+n-1`` are written
        and ``self._last[slot]`` holds the target distribution after the
        committed stream.  The verify feeds ``[t_0, d_1 .. d_k]`` with
        ``t_0 = argmax(_last)`` at ``q_start = P+n``, so row ``j``'s
        logits condition on exactly the tokens vanilla greedy would have
        committed — acceptance can only reproduce the vanilla stream, and
        every committed token's KV row was already written by the pass
        that scored it.  Each round commits at least one token (``t_0``),
        so ``verify_steps <= `` the baseline's decode steps, strictly
        fewer as soon as any draft is accepted.
        """
        k = self.spec_k
        C = k + 1
        bs = self.block_size
        # 1. drafter proposals, seeded with any committed tokens the
        # drafter has not ingested yet (lag <= 1 after an all-accept round)
        pending: dict[int, int] = {}
        jobs: list[tuple[int, list[int]]] = []
        for slot, req in spec:
            P = len(req.prompt)
            # an adopted slot's t_0 was already sampled (and delivered)
            # at the prefill replica's handoff — committing it below
            # restores the verify invariant without re-sampling
            pend = self._adopted_feed.pop(slot, None)
            t0 = (pend if pend is not None
                  else int(req.sampler.sample(self._last[slot][None])[0]))
            pending[slot] = t0
            dlen = self._drafter.length(slot)
            gap = [int(t) for t in req.output[dlen - P:]]
            jobs.append((slot, gap + [t0]))
        drafts = self._drafter.propose(jobs)
        # 2. provisional growth + batched verify over all spec slots
        tokens = np.zeros((self.slots, C), np.int32)
        qs = np.zeros((self.slots,), np.int32)
        kl = np.full((self.slots,), C, np.int32)  # padding rows see only
        mb_need = 1                               # trash-block garbage
        for slot, req in spec:
            q0 = len(req.prompt) + len(req.output)
            nb_need = -(-(q0 + C) // bs)
            grow = nb_need - len(req.block_ids)
            if grow > 0:
                # materialize provisional blocks out of the admission
                # reservation (which budgeted +spec_rows for exactly this)
                req.block_ids.extend(self.pool.alloc_reserved(grow))
                req.blocks_reserved -= grow
            tokens[slot, 0] = pending[slot]
            tokens[slot, 1:] = drafts[slot]
            qs[slot] = q0
            kl[slot] = q0 + C
            mb_need = max(mb_need, nb_need)
        mb_eff = 1
        while mb_eff < mb_need:
            mb_eff *= 2
        mb_eff = min(mb_eff, self.max_blocks)
        tbl = np.zeros((self.slots, mb_eff), np.int32)
        for slot, req in spec:
            tbl[slot, :len(req.block_ids)] = req.block_ids
        self._prefill_shapes.add((self.slots, C, mb_eff))
        logits, self._state = self._verify(
            self.params, jnp.asarray(tokens), self._state,
            jnp.asarray(tbl), jnp.asarray(qs), jnp.asarray(kl))
        logits = np.asarray(logits)              # (slots, C, V)
        # 3. vectorized longest-prefix acceptance
        rows = np.array([s for s, _ in spec])
        accepted, _ = greedy_accept_prefix(
            logits[rows], np.array([drafts[s] for s, _ in spec]))
        now = time.monotonic()
        for (slot, req), m in zip(spec, accepted):
            try:
                if self._fault("engine.decode", rid=req.rid) == "drop":
                    raise FaultError("engine.decode",
                                     f"dropped verify commit of {req.rid}")
            except Exception as e:  # noqa: BLE001 — poison isolation:
                # provisional rows already live in req.block_ids, so the
                # slot teardown frees them with the rest of the table
                self._fail_slot(slot, req, e)
                continue
            commit = [pending[slot]] + drafts[slot][:int(m)]
            commit = commit[:req.max_new_tokens - len(req.output)]
            self.totals.spec_proposed += k
            self.totals.spec_accepted += len(commit) - 1
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.extend(commit)
            self.totals.tokens += len(commit)
            # next-token logits after the last committed token: verify row
            # j conditions on commits[0..j], so row len(commit)-1 is it
            self._set_last(slot, logits[slot, len(commit) - 1])
            # trim the rejected tail's blocks back into the reservation
            nb_keep = -(-(len(req.prompt) + len(req.output)) // bs)
            tail = req.block_ids[nb_keep:]
            if tail:
                self.pool.release_provisional(tail)
                req.blocks_reserved += len(tail)
                del req.block_ids[nb_keep:]
            if len(req.output) >= req.max_new_tokens:
                req.state = RequestState.DONE
                req.finished_at = time.monotonic()
                self.scheduler.release(slot)
                self._retire_slot(slot)
                self._drafter.drop(slot)
                self._spec_on.discard(slot)
                if req.on_finish is not None:
                    req.on_finish(req)
            else:
                # drafter rows holding committed-stream tokens: the fed
                # t_0 plus accepted drafts d_1..d_{m} occupy rows up to
                # q_start + min(len(commit), k) - 1 (d_k is proposed but
                # never fed back)
                q0 = int(qs[slot])
                self._drafter.set_len(slot, q0 + min(len(commit), k))
        self._note_decode_cadence()
        self.totals.verify_steps += 1
        self.totals.occupancy_sum += len(spec) / self.slots

    # -- measurement windows ---------------------------------------------------

    def begin_window(self) -> "WindowBase":
        """Snapshot the lifetime counters (and reset the pool peak) so a
        caller can scope :class:`ServeStats` to one serving window — used
        by blocking :meth:`serve` and by service-mode drivers (benchmarks,
        the multi-replica engine), which previously had no way to get
        pool/preemption stats out of a live engine."""
        if self.pool is not None:
            self.pool.reset_peak()
        return WindowBase(
            tokens=self.totals.tokens, prefills=self.totals.prefills,
            decode_steps=self.totals.decode_steps,
            verify_steps=self.totals.verify_steps,
            spec_proposed=self.totals.spec_proposed,
            spec_accepted=self.totals.spec_accepted,
            occupancy_sum=self.totals.occupancy_sum,
            prefill_compiles=self.prefill_compiles,
            preemptions=self.scheduler.preemptions,
            prefix_shared=self.prefix_shared_total,
            prefill_tokens_total=self.totals.prefill_tokens_total,
            prefill_tokens_computed=self.totals.prefill_tokens_computed,
            decode_gap_n=self._gaps_dropped + len(self.totals.decode_gaps),
            kv_spills=self.totals.kv_spills,
            kv_fetches=self.totals.kv_fetches,
            prefix_hits_host=self.totals.prefix_hits_host,
            prefix_lookups=self.totals.prefix_lookups,
            spill_bytes=self.totals.spill_bytes,
            requests_failed=self.totals.requests_failed,
            shed_rejections=self.totals.shed_rejections,
            faults_injected=self.totals.faults_injected,
            kv_migrations=self.totals.kv_migrations,
            migrated_blocks=self.totals.migrated_blocks)

    def collect_window(self, base: "WindowBase", requests: list[Request],
                       wall_s: float) -> ServeStats:
        """Stats for everything this engine did since ``base`` (a
        :meth:`begin_window` snapshot), with per-request latency metrics
        filled from ``requests``."""
        stats = ServeStats(requests=len(requests), wall_s=wall_s)
        stats.tokens = self.totals.tokens - base.tokens
        stats.prefills = self.totals.prefills - base.prefills
        stats.decode_steps = self.totals.decode_steps - base.decode_steps
        stats.verify_steps = self.totals.verify_steps - base.verify_steps
        stats.spec_proposed = self.totals.spec_proposed - base.spec_proposed
        stats.spec_accepted = self.totals.spec_accepted - base.spec_accepted
        if stats.spec_proposed:
            stats.accept_rate = stats.spec_accepted / stats.spec_proposed
        stats.occupancy_sum = self.totals.occupancy_sum - base.occupancy_sum
        stats.prefill_compiles = self.prefill_compiles - base.prefill_compiles
        stats.preemptions = self.scheduler.preemptions - base.preemptions
        stats.prefix_shared_blocks = (self.prefix_shared_total
                                      - base.prefix_shared)
        stats.prefill_tokens_total = (self.totals.prefill_tokens_total
                                      - base.prefill_tokens_total)
        stats.prefill_tokens_computed = (self.totals.prefill_tokens_computed
                                         - base.prefill_tokens_computed)
        stats.kv_spills = self.totals.kv_spills - base.kv_spills
        stats.kv_fetches = self.totals.kv_fetches - base.kv_fetches
        stats.prefix_hits_host = (self.totals.prefix_hits_host
                                  - base.prefix_hits_host)
        stats.prefix_lookups = (self.totals.prefix_lookups
                                - base.prefix_lookups)
        stats.spill_bytes = self.totals.spill_bytes - base.spill_bytes
        stats.requests_failed = (self.totals.requests_failed
                                 - base.requests_failed)
        stats.shed_rejections = (self.totals.shed_rejections
                                 - base.shed_rejections)
        stats.faults_injected = (self.totals.faults_injected
                                 - base.faults_injected)
        stats.kv_migrations = (self.totals.kv_migrations
                               - base.kv_migrations)
        stats.migrated_blocks = (self.totals.migrated_blocks
                                 - base.migrated_blocks)
        if stats.prefix_lookups:
            stats.kv_hit_rate = ((stats.prefix_shared_blocks
                                  + stats.prefix_hits_host)
                                 / stats.prefix_lookups)
        stats.decode_gaps = list(self.totals.decode_gaps[
            max(0, base.decode_gap_n - self._gaps_dropped):])
        if self.pool is not None:
            stats.kv_blocks_peak = self.pool.peak_used
            stats.kv_pool_capacity = self.pool.capacity
            stats.kv_pool_util = self.pool.utilization
        stats.fill_request_metrics(requests)
        return stats

    # -- blocking mode ---------------------------------------------------------

    def serve(self, requests: list[Request]) -> ServeStats:
        """Continuous batching: admit everything, run the executor until
        every request is DONE."""
        assert self._thread is None, "engine already running in service mode"
        for r in requests:
            self._check_fits(r)
        base = self.begin_window()
        t0 = time.monotonic()
        for r in requests:
            self.scheduler.submit(r)
        while self.scheduler.has_work():
            try:
                self._step()
            except Exception as e:  # noqa: BLE001 — crash capture: fail
                # every in-flight request (freeing its blocks) before the
                # crash surfaces, so the pool stays leak-free even when
                # the executor dies mid-batch
                self._record_crash(e)
                raise
        return self.collect_window(base, requests, time.monotonic() - t0)

    # -- service mode (used by the replica router and live traffic) ------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._service_loop,
                                        name="serving-executor", daemon=True)
        self._thread.start()

    def _service_loop(self) -> None:
        try:
            while not self._stop.is_set():
                if not self.scheduler.wait_for_work(timeout=0.02):
                    continue
                self._step()
        except Exception as e:  # noqa: BLE001 — crash capture: the
            # executor must not die silently; record the failure, fail
            # every in-flight request (freeing its KV blocks), and poison
            # the scheduler so later submitters see ExecutorCrash instead
            # of a hang.  stop()/failure re-surface the exception.
            self._record_crash(e)

    def submit(self, req: Request,
               on_finish: Callable[[Request], None] | None = None) -> None:
        """Thread-safe admission; ``on_finish`` fires from the executor
        thread the moment the request's last token is emitted.

        Raises :class:`ExecutorCrash` (chained to the original failure)
        if the executor has died, and :class:`ShedError` when the queue
        is already ``shed_queue_depth`` deep — an admission there could
        only miss its SLO, so shedding it early is the graceful
        degradation mode."""
        crash = self.failure
        if crash is not None:
            raise ExecutorCrash(
                "executor is dead; submit refused") from crash
        if self.shed_queue_depth is not None:
            depth = self.scheduler.queued
            if depth >= self.shed_queue_depth:
                with self._ctl_lock:
                    self.totals.shed_rejections += 1
                raise ShedError(
                    f"queue depth {depth} >= shed threshold "
                    f"{self.shed_queue_depth}")
        self._check_fits(req)
        req.replica = self.name
        if on_finish is not None:
            req.on_finish = on_finish
        self.scheduler.submit(req)

    def adopt_blocks(self, req: Request, keys: list, tokens: np.ndarray,
                     blocks: list, last: np.ndarray) -> int:
        """Thread-safe admission of a *migrated* prefill — the receiver
        half of the disaggregated handoff, called on the migration
        worker.  Stages the payload and queues the request; the executor
        lands the rows into freshly allocated pool blocks at admission
        (:meth:`_adopt_slot`) and enters DECODE without recomputing a
        single prompt token.

        Unlike :meth:`submit` there is no shed check: the prefill
        compute is already spent, so shedding here would waste it (the
        request was shed-checked at its original admission).  Raises
        ``CapacityError`` / :class:`ExecutorCrash` like submit; the
        migration completion hook turns either into the
        retry-from-bare-prompt path.  Returns the number of blocks
        staged — the migrate payload's success result."""
        req.replica = self.name    # before any raise: failures inside the
        #                            adopt are charged to *this* replica
        crash = self.failure
        if crash is not None:
            raise ExecutorCrash(
                "executor is dead; adopt refused") from crash
        self._check_fits(req)
        # the seq was minted by the source scheduler's heap; this pool's
        # heap must assign its own tiebreak (cross-scheduler seqs never
        # compare), exactly like a stolen request
        req.arrival_seq = None
        with self._adopt_lock:
            self._adoptions[req.rid] = _Adoption(
                req=req, keys=keys, tokens=tokens, blocks=blocks,
                last=last)
        try:
            self.scheduler.submit(req)
        except BaseException:
            with self._adopt_lock:
                self._adoptions.pop(req.rid, None)
            raise
        return len(blocks)

    def stop(self, timeout: float = 10.0, *,
             raise_failure: bool = True) -> None:
        """Stop the service-mode executor thread; idempotent, safe to
        call twice and after a crash.  Raises if a live thread does not
        exit within ``timeout`` — and keeps the handle, so a later
        :meth:`start` cannot race two executors over ``_state``.  If the
        executor died on a non-request fault, that crash is re-raised
        here exactly once (``raise_failure=False`` suppresses it — the
        router uses this after it has already routed the failure)."""
        thread = self._thread
        if thread is not None:
            self._stop.set()
            thread.join(timeout=timeout)
            if thread.is_alive():
                raise RuntimeError(
                    f"executor thread did not stop within {timeout}s; "
                    f"handle retained — a second start() would race two "
                    f"executors over the decode state")
            self._thread = None
        if raise_failure:
            self._raise_failure_once()

    @property
    def load(self) -> int:
        return self.scheduler.load

    def load_snapshot(self) -> LoadSnapshot:
        """Block-aware load triple (free slots, free KV blocks, queued
        prefill tokens) the replica router places and steals on — the raw
        request count in :attr:`load` hides pool starvation."""
        return self.scheduler.load_snapshot()

    # -- legacy wave decode (seed behaviour, kept for A/B benchmarking) --------

    def serve_wave(self, requests: list[Request]) -> ServeStats:
        """The seed's lock-step path: bucket by prompt length, prefill each
        wave batched, decode until every wave member finishes.  A finished
        slot idles until the slowest request in its wave completes — kept
        only as the baseline `benchmarks/serving_bench.py` compares
        continuous batching against."""
        for r in requests:
            self._check_fits(r)
        stats = ServeStats(requests=len(requests))
        compiles0 = self.prefill_compiles
        t0 = time.monotonic()
        for r in requests:          # wave path bypasses scheduler.submit()
            if r.submitted_at is None:
                r.submitted_at = t0
        buckets: dict[int, list[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for _, bucket in sorted(buckets.items()):
            for w0 in range(0, len(bucket), self.slots):
                wave = bucket[w0:w0 + self.slots]
                prompts = np.stack([r.prompt for r in wave])
                self._prefill_shapes.add(prompts.shape)
                last, state = self._prefill(self.params,
                                            self._batch_for(prompts))
                stats.prefills += 1
                active = np.ones(len(wave), bool)
                n_steps = max(r.max_new_tokens for r in wave)
                for _ in range(n_steps):
                    toks = []
                    for i, r in enumerate(wave):
                        tok = int(r.sampler(np.asarray(last[i])))
                        if active[i]:
                            if r.first_token_at is None:
                                r.first_token_at = time.monotonic()
                            r.output.append(tok)
                            stats.tokens += 1
                            if len(r.output) >= r.max_new_tokens:
                                active[i] = False
                                r.state = RequestState.DONE
                                r.finished_at = time.monotonic()
                        toks.append(tok)
                    if not active.any():
                        break
                    last, state = self._decode(
                        self.params, jnp.asarray(toks, jnp.int32)[:, None],
                        state)
                    stats.decode_steps += 1
                    stats.occupancy_sum += active.sum() / self.slots
        stats.wall_s = time.monotonic() - t0
        stats.prefill_compiles = self.prefill_compiles - compiles0
        stats.fill_request_metrics(requests)
        return stats


# -- moved to repro.serving.router (deprecation shim) --------------------------

_MOVED_TO_ROUTER = ("MultiReplicaEngine", "ReplicaTarget")


def __getattr__(name: str):
    """PEP-562 shim: the multi-replica classes live in
    `repro.serving.router` now; importing them from here still works but
    warns, so downstream callers migrate before the shim goes away."""
    if name in _MOVED_TO_ROUTER:
        import warnings
        warnings.warn(
            f"repro.serving.engine.{name} moved to repro.serving.router; "
            f"update the import — this shim will be removed in a later PR",
            DeprecationWarning, stacklevel=2)
        from repro.serving import router
        return getattr(router, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
