"""int8 gradient compression with error feedback for the cross-pod (DCN)
all-reduce.

Cross-pod links are the slowest tier (DCN vs in-pod ICI), and gradients
cross them once per step under pod-level data parallelism.  Quantizing the
pod-to-pod payload to int8 (per-tensor absmax scale) cuts DCN bytes 4x vs
fp32 / 2x vs bf16; the quantization residual is carried in an error-
feedback buffer so the accumulated gradient signal stays unbiased across
steps (the 1-bit-Adam argument).

The building block here is `compressed_cross_pod_mean`, a shard_map over
the ``pod`` axis; enabling it for a train step is a documented §Perf lever
(it trades DCN bytes against one extra quant/dequant pass per step).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Pytree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric absmax int8 quantization."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(x: jax.Array, err: jax.Array):
    """Quantize (x + carried error); return (q, scale, new_error)."""
    target = x.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def init_error_feedback(grads: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _pod_body(g, e, *, pod_axis: str):
    """Per-pod body: g/e are (1, ...) local slices of the pod-stacked grads."""
    q, scale, new_err = ef_quantize(g[0], e[0])
    summed = jax.lax.psum(dequantize_int8(q, scale), pod_axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), pod_axis)
    return (summed / n).astype(g.dtype), new_err[None]


def compressed_cross_pod_mean(per_pod: Pytree, err: Pytree, mesh, *,
                              pod_axis: str = "pod"):
    """Cross-pod mean of per-pod gradients with int8 payloads + EF.

    Args:
      per_pod: pytree whose leaves are (n_pod, ...) — pod-stacked partial
        gradients, sharded over ``pod_axis`` on the leading dim.
      err: matching error-feedback buffers (same shapes).
    Returns:
      (mean pytree with leaves (...), updated err pytree (n_pod, ...)).
    """
    fn = jax.shard_map(
        partial(_pod_body, pod_axis=pod_axis), mesh=mesh,
        in_specs=(P(pod_axis), P(pod_axis)),
        out_specs=(P(), P(pod_axis)),
        check_vma=False)
    flat_g, tdef = jax.tree_util.tree_flatten(per_pod)
    flat_e = jax.tree_util.tree_leaves(err)
    outs = [fn(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs]))
