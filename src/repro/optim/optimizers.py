"""Optimizers (hand-rolled, sharding-aware): AdamW and Adafactor.

Each optimizer also derives the *logical axes* of its state from the
parameter axes, so `distributed.sharding` can build NamedShardings for the
optimizer state exactly like it does for parameters (Adafactor's factored
vectors inherit the row/col axes of the parameter they factor).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]
    state_axes: Callable[[Pytree], Pytree]


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(schedule: Callable[[jax.Array], jax.Array], *,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, max_grad_norm: float = 1.0) -> Optimizer:

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr = schedule(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)   # per-leaf cast: no full fp32 copy
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m2 / c1
            vhat = v2 / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            new_p = p32 - lr * (delta + weight_decay * p32)
            return new_p.astype(p.dtype), m2, v2

        flat = jax.tree_util.tree_map(upd, params, grads,
                                      state["mu"], state["nu"])
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics

    def state_axes(param_axes):
        return {
            "mu": param_axes,
            "nu": param_axes,
            "step": (),
        }

    return Optimizer(init=init, update=update, state_axes=state_axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment over the last two dims; no momentum)
# ---------------------------------------------------------------------------

def _factored(p_shape) -> bool:
    return len(p_shape) >= 2 and p_shape[-1] > 1 and p_shape[-2] > 1


def adafactor(schedule: Callable[[jax.Array], jax.Array], *,
              decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0,
              max_grad_norm: float = 1.0) -> Optimizer:

    def init(params):
        def mk(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "v": jax.tree_util.tree_map(mk, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr = schedule(step)
        # time-dependent decay (Adafactor beta2 schedule)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, v):
            g = g.astype(jnp.float32)   # per-leaf cast: no full fp32 copy
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                    + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                delta = g * rfac[..., None] * cfac[..., None, :]
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                delta = g * jax.lax.rsqrt(vv + eps)
                new_v = {"v": vv}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms / clip_threshold)
            p32 = p.astype(jnp.float32)
            new_p = p32 - lr * (delta + weight_decay * p32)
            return new_p.astype(p.dtype), new_v

        is_v = lambda t: isinstance(t, dict) and ("vr" in t or "v" in t)
        flat = jax.tree_util.tree_map(upd, params, grads, state["v"],
                                      is_leaf=lambda t: False)
        # tree_map over params zips structures; flat leaves are tuples
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, {"v": new_v, "step": step}, metrics

    def state_axes(param_axes):
        def mk(ax):
            ax = tuple(ax)
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}
        return {
            "v": jax.tree_util.tree_map(mk, param_axes,
                                        is_leaf=lambda t: isinstance(t, tuple)),
            "step": (),
        }

    return Optimizer(init=init, update=update, state_axes=state_axes)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return schedule


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def make_optimizer(cfg, *, peak_lr: float = 3e-4, warmup: int = 200,
                   total: int = 10_000) -> Optimizer:
    sched = warmup_cosine(peak_lr, warmup, total)
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched)
