"""Power accounting (paper §5, Eq. 1): Throughput_Watt = (items/s) / TDP.

TDP models for the paper's devices and for the TPU v5e target live in
`repro.roofline.hw`; this module turns offload/benchmark stats into the
paper's img/W metric and the LM-serving analogues (tokens/s/W, tokens/J).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.hw import (CHIPS, MYRIAD2_VPU, NCS_STICK_PEAK_WATTS,
                               QUADRO_K4000, TPU_V5E, XEON_E5_2609V2, ChipSpec)

# Paper-calibrated single-inference latencies (Fig 6b normalization bases).
PAPER_LATENCY_S = {
    "vpu": 0.1007,     # Myriad 2 VPU, single NCS
    "cpu": 0.0260,     # dual Xeon E5-2609v2, Caffe-MKL
    "gpu": 0.0259,     # Quadro K4000, Caffe-cuDNN
}
# Paper-reported batch-8 throughputs (Fig 6a), img/s.
PAPER_THROUGHPUT_8 = {"vpu": 77.2, "cpu": 44.0, "gpu": 74.2}

PAPER_TDP_W = {
    "vpu": MYRIAD2_VPU.tdp_watts,        # 0.9 W chip (2.5 W stick peak)
    "cpu": XEON_E5_2609V2.tdp_watts,     # 80 W
    "gpu": QUADRO_K4000.tdp_watts,       # 80 W
}


def throughput_per_watt(items_per_s: float, tdp_watts: float) -> float:
    """Paper Eq. (1)."""
    return items_per_s / tdp_watts


def joules_per_item(items_per_s: float, tdp_watts: float) -> float:
    return tdp_watts / items_per_s if items_per_s else float("inf")


@dataclass(frozen=True)
class PowerReport:
    device: str
    n_devices: int
    items_per_s: float
    tdp_watts_total: float

    @property
    def items_per_watt(self) -> float:
        return throughput_per_watt(self.items_per_s, self.tdp_watts_total)

    @property
    def joules_per_item(self) -> float:
        return joules_per_item(self.items_per_s, self.tdp_watts_total)

    def row(self) -> str:
        return (f"{self.device:>14s} x{self.n_devices:<3d} "
                f"{self.items_per_s:10.2f} items/s  "
                f"{self.tdp_watts_total:8.1f} W  "
                f"{self.items_per_watt:8.3f} items/W  "
                f"{self.joules_per_item:8.3f} J/item")


def report(device: str, n_devices: int, items_per_s: float,
           *, per_device_watts: float | None = None) -> PowerReport:
    if per_device_watts is None:
        per_device_watts = PAPER_TDP_W.get(device, TPU_V5E.tdp_watts)
    return PowerReport(device=device, n_devices=n_devices,
                       items_per_s=items_per_s,
                       tdp_watts_total=per_device_watts * n_devices)


def tpu_serving_report(tokens_per_s: float, chips: int) -> PowerReport:
    """LM-serving analogue of the paper's metric on the v5e target."""
    return PowerReport(device=TPU_V5E.name, n_devices=chips,
                       items_per_s=tokens_per_s,
                       tdp_watts_total=TPU_V5E.tdp_watts * chips)
