"""The paper's contribution, generalized: split-phase co-processor offload.

NCSw (paper §3) maps onto this module as follows:

  NCAPI ``mvncLoadTensor``  -> :meth:`Target.load_tensor` (non-blocking:
                               stage input + enqueue execution)
  NCAPI ``mvncGetResult``   -> :meth:`Target.get_result` (blocking collect,
                               queueing order)
  one host thread per NCS   -> one worker thread per :class:`Target`
  static round-robin        -> :class:`OffloadEngine` scheduler="round_robin"
  USB transfer/compute overlap -> per-target transfer stage runs in the
                               worker while the previous item computes

Beyond the paper (1000+-node posture): deadline-based straggler reissue
(a stuck device's item is re-dispatched to the next free target; first
result wins), dynamic least-loaded scheduling as an alternative to static
round-robin, a pluggable placement hook (``scheduler=callable``) so higher
layers like the serving replica router can score targets themselves, and
target groups so one engine can drive heterogeneous pools (the paper's
"subset on a GPU, subsets on VPU groups").

Two collection disciplines coexist:

  * :meth:`OffloadEngine.run` — ordered collection (``inflight.pop(0)``),
    exactly the paper's Fig 4 queueing-order semantics; used by the
    figure-reproduction benchmarks.
  * :meth:`OffloadEngine.submit_async` + :meth:`next_done` /
    :meth:`drain` / :meth:`run_unordered` — out-of-order completion via a
    per-engine done-queue, so one slow item never blocks draining of
    finished ones.  This is what the continuous-batching serving scheduler
    rides on: the replica pull-loop collects whichever request finishes
    first, with no head-of-line blocking.

Targets:
  * :class:`JaxTarget` — executes a jitted fn on a JAX device (real compute).
  * :class:`SimTarget` — calibrated latency model of a paper device (Myriad 2
    VPU / Xeon / Quadro), used to reproduce the paper's scaling figures
    deterministically on this CPU-only host.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np


@dataclass
class WorkError:
    """Terminal error result: every attempt at the item raised.

    Committed through the normal :meth:`WorkItem.complete` path so
    collectors (``run``/``run_unordered``/``drain``) terminate instead of
    hanging on an item nothing will ever finish; consumers distinguish it
    with ``isinstance(item.result, WorkError)``.
    """
    error: BaseException
    target_name: str = ""


@dataclass
class WorkItem:
    seq: int
    payload: Any
    submitted_at: float = field(default_factory=time.monotonic)
    started_at: float | None = None
    finished_at: float | None = None
    result: Any = None
    target_name: str = ""
    reissued: bool = False
    failures: int = 0           # raising attempts (retries ride on this)
    done: threading.Event = field(default_factory=threading.Event)
    # async completion hook (set by OffloadEngine.submit); fired exactly once,
    # by whichever target completes the item first (reissue-safe).
    on_done: Callable[["WorkItem"], None] | None = None
    # failure hook: (item, exc, target_name) -> True if the failure was
    # *handled* (e.g. the router reissued the item on a survivor); False
    # lets fail() commit a WorkError so collectors still terminate.
    on_fail: Callable[["WorkItem", BaseException, str], bool] | None = None
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def complete(self, result: Any, target_name: str) -> bool:
        """First-completion-wins commit; returns False if already done."""
        with self._lock:
            if self.done.is_set():
                return False
            self.result = result
            self.target_name = target_name
            self.finished_at = time.monotonic()
            self.done.set()
        if self.on_done is not None:
            self.on_done(self)
        return True

    def fail(self, exc: BaseException, target_name: str) -> bool:
        """Route one raising attempt: give ``on_fail`` a chance to handle
        it (retry elsewhere); otherwise commit a :class:`WorkError` result
        so whoever is collecting this item unblocks with a typed failure
        instead of waiting forever.  Returns True if the item reached a
        terminal state here."""
        with self._lock:
            if self.done.is_set():
                return False
            self.failures += 1
        if self.on_fail is not None:
            try:
                if self.on_fail(self, exc, target_name):
                    return False          # handled: item lives on elsewhere
            except Exception:  # fault-ok: a broken failure handler must not kill the worker; fall through to the terminal WorkError commit
                pass
        return self.complete(WorkError(error=exc, target_name=target_name),
                             target_name)


class Target:
    """A co-processor endpoint (paper's abstract Target)."""

    name: str = "target"
    tdp_watts: float = 1.0
    # fault-injection probe (``target.compute`` site): called with the
    # item just before execute; returning True *drops* the item (completes
    # with None — a silently-lost result), raising routes through the
    # normal failure path, and a delay action sleeps inside the hook.
    fault_hook: Callable[[WorkItem], bool] | None = None

    def transfer(self, payload: Any) -> Any:
        """Host->device staging (USB transfer analogue)."""
        return payload

    def execute(self, staged: Any) -> Any:
        raise NotImplementedError

    # -- split-phase API (NCAPI semantics) -------------------------------------

    def open(self) -> None:
        self._q: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._run,
                                        name=f"offload-{self.name}",
                                        daemon=True)
        self._alive = True
        self.busy = False
        self._worker.start()

    def close(self) -> None:
        self._alive = False
        self._q.put(None)
        self._worker.join(timeout=5)

    def load_tensor(self, item: WorkItem) -> WorkItem:
        """Non-blocking: stage input + enqueue execution (mvncLoadTensor)."""
        self._q.put(item)
        return item

    @staticmethod
    def get_result(item: WorkItem, timeout: float | None = None) -> Any:
        """Blocking collect (mvncGetResult)."""
        if not item.done.wait(timeout):
            raise TimeoutError(f"item {item.seq} not done")
        return item.result

    def _run(self) -> None:
        while self._alive:
            item = self._q.get()
            if item is None:
                return
            if item.done.is_set():     # straggler reissue already finished it
                continue
            self.busy = True
            try:
                staged = self.transfer(item.payload)
                item.started_at = time.monotonic()
                if self.fault_hook is not None and self.fault_hook(item):
                    item.complete(None, self.name)   # injected drop
                    continue
                out = self.execute(staged)
                item.complete(out, self.name)
            except Exception as e:  # noqa: BLE001 — routed, not swallowed:
                # a raising transfer/execute used to kill this worker and
                # hang the item's collector; fail() keeps both alive
                item.fail(e, self.name)
            finally:
                self.busy = False

    @property
    def queue_depth(self) -> int:
        return self._q.qsize() + (1 if self.busy else 0)


class JaxTarget(Target):
    """Runs a jitted function; inputs staged via device_put (double buffer)."""

    def __init__(self, fn: Callable, name: str = "jax",
                 tdp_watts: float = 1.0, device=None):
        self.fn = fn
        self.name = name
        self.tdp_watts = tdp_watts
        self.device = device

    def transfer(self, payload):
        import jax
        if self.device is not None:
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(x, self.device), payload)
        return payload

    def execute(self, staged):
        out = self.fn(staged)
        import jax
        return jax.tree_util.tree_map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, out)


class KVBlockTarget(Target):
    """KV-block transfer endpoint: the serving tier hierarchy's host tier
    driven as a split-phase offload device (paper Fig-4 applied to KV
    cache blocks instead of weight tensors).

    ``tier`` is duck-typed (`repro.serving.kv_pool.HostTier` in practice)
    so the core layer stays free of serving imports.  Payloads:

      ``("spill", key, leaves)`` — materialize one block's device slices
          (a dict of per-leaf jax arrays, captured immutably by the engine
          before the block id was freed) into host numpy and store them
          under ``key``; result = bytes moved.  The device->host copy —
          the blocking part — runs here on the worker, overlapped with
          the engine's decode steps.
      ``("fetch", key)`` — load ``key``'s payload (dict of numpy arrays),
          or None if the tier has since evicted it (the engine falls back
          to recompute).
      ``("migrate", rid, keys, tables, leaves, gens)`` — move one
          finished prefill's whole block set (per-block leaf dicts in
          table order, plus the chained prefix digests and source
          generation tags that make the payload self-describing) to a
          peer replica via the tier's ``adopt`` hook; result = whatever
          ``adopt`` returns (None = the receiver declined).  The
          device->host materialization happens here on the worker, so
          the source replica's executor never blocks on the copy.

    One worker drains the queue FIFO, so a fetch submitted behind its own
    spill always finds the stored payload.
    """

    def __init__(self, tier, name: str = "kv_host", tdp_watts: float = 0.0):
        self.tier = tier
        self.name = name
        self.tdp_watts = tdp_watts

    def execute(self, staged):
        if staged[0] == "spill":
            _, key, leaves = staged
            host = {k: np.asarray(v) for k, v in leaves.items()}
            self.tier.store(key, host)
            return sum(int(a.nbytes) for a in host.values())
        if staged[0] == "migrate":
            _, rid, keys, tables, leaves, gens = staged
            host = [{k: np.asarray(v) for k, v in blk.items()}
                    for blk in leaves]
            return self.tier.adopt(rid, keys, tables, host, gens)
        _, key = staged
        return self.tier.load(key)


class SimTarget(Target):
    """Latency-calibrated stand-in for a paper device.

    The paper's single-device latencies (Fig 6b baselines): VPU 100.7 ms,
    CPU 26.0 ms, GPU 25.9 ms per inference; we split VPU time into a USB
    transfer share and SHAVE compute so transfer/compute overlap matters,
    exactly like the real NCS.
    """

    def __init__(self, name: str, compute_s: float, transfer_s: float = 0.0,
                 tdp_watts: float = 1.0, result_fn: Callable | None = None):
        self.name = name
        self.compute_s = compute_s
        self.transfer_s = transfer_s
        self.tdp_watts = tdp_watts
        self.result_fn = result_fn or (lambda p: p)

    def transfer(self, payload):
        if self.transfer_s:
            time.sleep(self.transfer_s)
        return payload

    def execute(self, staged):
        time.sleep(self.compute_s)
        return self.result_fn(staged)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class OffloadStats:
    items: int = 0
    wall_s: float = 0.0
    reissues: int = 0
    per_target: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.items / self.wall_s if self.wall_s else 0.0


class OffloadEngine:
    """Coordinates N targets with the paper's split-phase protocol."""

    def __init__(self, targets: Sequence[Target], *,
                 scheduler: str | Callable[[list[Target], Any], Target]
                 = "round_robin",
                 deadline_s: float | None = None):
        # ``scheduler`` may be a placement hook: callable(targets, payload)
        # -> Target.  Higher layers (the serving ReplicaRouter) score
        # placement themselves — prefix affinity, block-aware load — while
        # riding this engine's split-phase submit/drain/reissue machinery
        # unchanged.
        assert callable(scheduler) or scheduler in ("round_robin",
                                                    "least_loaded")
        self.targets = list(targets)
        self.scheduler = scheduler
        self.deadline_s = deadline_s
        # Leaf lock for the engine's own counters/maps.  Submissions come
        # from several threads at once (the serve loop's submit_async, a
        # serving engine's spill submits from *inside* the pool lock, the
        # tier drain's next_done on the executor thread), so these need a
        # lock — but it is never held across _pick (a placement hook may
        # take scheduler/pool locks: router._place -> load_snapshot) or
        # load_tensor, which keeps it a leaf in the acquisition order and
        # the lock-order graph cycle-free.
        self._lock = threading.Lock()
        self._rr = 0                          # guarded-by: self._lock
        self._seq = 0                         # guarded-by: self._lock
        self._open = False
        self._done_q: queue.Queue = queue.Queue()
        self._async_pending: dict[int, WorkItem] = {}  # guarded-by: self._lock

    def __enter__(self):
        for t in self.targets:
            t.open()
        self._open = True
        return self

    def __exit__(self, *exc):
        self._open = False
        errors = []
        for t in self.targets:     # close every target even if one raises
            try:
                t.close()
            except Exception as e:  # noqa: BLE001 — aggregated below
                errors.append(e)
        # never mask an in-flight exception from the with-body; close
        # errors stay inspectable either way
        self.close_errors = errors
        if errors and exc[0] is None:
            if len(errors) == 1:
                raise errors[0]
            raise RuntimeError(
                f"{len(errors)} targets failed to close: "
                + "; ".join(repr(e) for e in errors)) from errors[0]

    def _pick(self, payload: Any) -> Target:
        if callable(self.scheduler):
            return self.scheduler(self.targets, payload)
        if self.scheduler == "round_robin":
            with self._lock:
                idx = self._rr
                self._rr += 1
            return self.targets[idx % len(self.targets)]
        return min(self.targets, key=lambda t: t.queue_depth)

    def submit(self, payload: Any, *,
               on_done: Callable[[WorkItem], None] | None = None) -> WorkItem:
        """Split-phase load (returns immediately; result via get_result).

        ``on_done`` fires exactly once, from the completing target's worker
        thread, the moment the item finishes — the async-notify alternative
        to blocking in :meth:`get_result`.
        """
        with self._lock:              # leaf: released before _pick/dispatch
            seq = self._seq
            self._seq += 1
        item = WorkItem(seq=seq, payload=payload, on_done=on_done)
        self._pick(payload).load_tensor(item)
        return item

    def submit_async(self, payload: Any) -> WorkItem:
        """Submit with completion routed to the engine's done-queue, so a
        consumer loop can collect items out of order via :meth:`next_done`
        / :meth:`drain` without head-of-line blocking."""
        item = self.submit(payload, on_done=self._done_q.put)
        with self._lock:
            self._async_pending[item.seq] = item
        return item

    def next_done(self, timeout: float | None = None) -> WorkItem | None:
        """Pop the next completed async item (any order); None on timeout.

        Retires the item from the async-pending set here (``drain``'s own
        pop is then a no-op), so a consumer loop that collects via
        ``next_done`` directly — the serving engine's KV-tier drain —
        cannot leak pending entries."""
        try:
            item = self._done_q.get(timeout=timeout)
        except queue.Empty:
            return None
        with self._lock:
            self._async_pending.pop(item.seq, None)
        return item

    def drain(self, n: int, *, deadline_s: float | None = None):
        """Yield ``n`` completed async items as they finish (out of order).

        With ``deadline_s`` (falls back to the engine's), a quiet period
        longer than the deadline triggers straggler reissue of every
        outstanding async item on the least-loaded target; first completion
        wins (``WorkItem.complete`` guards double-commit).
        """
        deadline = deadline_s if deadline_s is not None else self.deadline_s
        got = 0
        while got < n:
            item = self.next_done(timeout=deadline)
            if item is None:          # quiet past deadline -> reissue stragglers
                alt = min(self.targets, key=lambda t: t.queue_depth)
                with self._lock:      # snapshot only; dispatch outside
                    pending = list(self._async_pending.values())
                for it in pending:
                    # at most one reissue per item (same as get_result):
                    # repeating it would admit duplicate clones every quiet
                    # period on replica-style targets
                    if not it.done.is_set() and not it.reissued:
                        it.reissued = True
                        alt.load_tensor(it)
                item = self._done_q.get()
            with self._lock:
                self._async_pending.pop(item.seq, None)
            got += 1
            yield item

    def get_result(self, item: WorkItem) -> Any:
        if self.deadline_s is None:
            return Target.get_result(item)
        # deadline-based straggler mitigation: reissue on the least-loaded
        # other target; first completion wins.
        if item.done.wait(self.deadline_s):
            return item.result
        item.reissued = True
        alt = min(self.targets, key=lambda t: t.queue_depth)
        alt.load_tensor(item)
        return Target.get_result(item)

    def run(self, payloads, *, window: int | None = None) -> tuple[list, OffloadStats]:
        """Pipeline a stream: keep ``window`` items in flight (defaults to
        2x targets — the paper's double-buffering), collect in order."""
        assert self._open, "use `with OffloadEngine(...) as eng:`"
        window = window or 2 * len(self.targets)
        results: list[Any] = []
        stats = OffloadStats()
        inflight: list[WorkItem] = []
        t0 = time.monotonic()
        it = iter(payloads)
        exhausted = False
        while not exhausted or inflight:
            while not exhausted and len(inflight) < window:
                try:
                    inflight.append(self.submit(next(it)))
                except StopIteration:
                    exhausted = True
            item = inflight.pop(0)        # queueing order (paper Fig 4)
            results.append(self.get_result(item))
            stats.items += 1
            stats.reissues += int(item.reissued)
            stats.per_target[item.target_name] = \
                stats.per_target.get(item.target_name, 0) + 1
        stats.wall_s = time.monotonic() - t0
        return results, stats

    def run_unordered(self, payloads, *,
                      window: int | None = None) -> tuple[list, OffloadStats]:
        """Pipeline a stream with out-of-order collection: results are
        ``(seq, result)`` pairs in *completion* order.  Keeps ``window``
        items in flight; a straggler (engine ``deadline_s``) is reissued on
        the least-loaded target and never blocks draining of later items."""
        assert self._open, "use `with OffloadEngine(...) as eng:`"
        window = window or 2 * len(self.targets)
        payloads = list(payloads)
        stats = OffloadStats()
        results: list[tuple[int, Any]] = []
        t0 = time.monotonic()
        nxt = 0
        while nxt < len(payloads) and nxt < window:
            self.submit_async(payloads[nxt])
            nxt += 1
        for item in self.drain(len(payloads)):
            results.append((item.seq, item.result))
            stats.items += 1
            stats.reissues += int(item.reissued)
            stats.per_target[item.target_name] = \
                stats.per_target.get(item.target_name, 0) + 1
            if nxt < len(payloads):
                self.submit_async(payloads[nxt])
                nxt += 1
        stats.wall_s = time.monotonic() - t0
        return results, stats
