"""Precision policies + the paper's §4.2 error-delta estimators.

The paper checks that FP16 on the VPU is inference-safe vs the FP32 CPU
reference: (a) top-1 error differs by only 0.09 %, (b) mean absolute
confidence difference (on top-1-correct images) is 0.44 %.  We reproduce
both estimators exactly; on TPU the reduced precision of interest is bf16
(and fp16 for parity with the paper), so the policy covers both.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cast_tree


@dataclass(frozen=True)
class PrecisionPolicy:
    """What dtype each tensor class uses."""
    name: str
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    cache_dtype: str = "bfloat16"

    def apply_to_config(self, cfg):
        return cfg.replace(param_dtype=self.param_dtype,
                           compute_dtype=self.compute_dtype)

    def cast_params(self, params):
        return cast_tree(params, self.param_dtype)


FP32 = PrecisionPolicy("fp32")
BF16 = PrecisionPolicy("bf16", param_dtype="float32",
                       compute_dtype="bfloat16")
FP16 = PrecisionPolicy("fp16", param_dtype="float16",
                       compute_dtype="float16", cache_dtype="float16")
POLICIES = {p.name: p for p in (FP32, BF16, FP16)}


# --- paper §4.2 estimators ---------------------------------------------------

def top1_error_rate(probs: np.ndarray, labels: np.ndarray) -> float:
    """Fraction of images whose argmax != label (top-1 estimation)."""
    pred = np.argmax(probs, axis=-1)
    return float(np.mean(pred != labels))


def top1_delta(probs_a: np.ndarray, probs_b: np.ndarray,
               labels: np.ndarray) -> float:
    """|top-1 error(a) - top-1 error(b)| (paper Fig 7a quantity)."""
    return abs(top1_error_rate(probs_a, labels) -
               top1_error_rate(probs_b, labels))


def confidence_delta(probs_a: np.ndarray, probs_b: np.ndarray,
                     labels: np.ndarray) -> float:
    """Mean |confidence_a - confidence_b| over images both predict correctly
    ("after filtering the top-1 miss-predictions", paper Fig 7b)."""
    pa, pb = np.argmax(probs_a, -1), np.argmax(probs_b, -1)
    both = (pa == labels) & (pb == labels)
    if not np.any(both):
        return float("nan")
    ca = np.max(probs_a, -1)[both]
    cb = np.max(probs_b, -1)[both]
    return float(np.mean(np.abs(ca - cb)))


def prediction_agreement(probs_a: np.ndarray, probs_b: np.ndarray) -> float:
    """Fraction of inputs where both precisions pick the same top-1 class."""
    return float(np.mean(np.argmax(probs_a, -1) == np.argmax(probs_b, -1)))
