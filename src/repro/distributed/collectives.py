"""Explicit collectives: distributed flash-decode (LSE merge) over a
sequence-sharded KV cache.

During decode the KV cache dominates memory (e.g. llama3-405b decode_32k:
~2.2 TB global).  We shard its sequence dim over the ``model`` axis; each
shard computes attention over its local slots + log-sum-exp residuals, and
partials merge with an all-gather of (out, m, l) — O(B*H*D) bytes, tiny
next to the cache.  This is the TPU adaptation of flash-decoding's split-K,
and the direct analogue of the paper's multi-device result collection.

The new token's K/V row is written only by the shard that owns the slot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, current_rules
from repro.models.layers.attention import (AttnResiduals, chunked_attention,
                                           merge_lse)


def _write_row(buf, row, lengths, offset, s_loc):
    """Scatter one new (B, ...) row at slot (lengths - offset) if owned."""
    B = buf.shape[0]
    widx = lengths - offset
    in_range = (widx >= 0) & (widx < s_loc)
    widx_c = jnp.clip(widx, 0, s_loc - 1)
    upd = buf.at[jnp.arange(B), widx_c].set(row.astype(buf.dtype))
    sel = in_range.reshape((B,) + (1,) * (buf.ndim - 1))
    return jnp.where(sel, upd, buf)


def _local_decode(q, ck, cv, nk, nv, lengths, *scales, seq_axis, softcap,
                  chunk):
    """Per-shard body under shard_map. With ``scales`` (k_scale, v_scale)
    the cache is int8 and new rows are quantized on write."""
    s_loc = ck.shape[1]
    m_id = jax.lax.axis_index(seq_axis)
    offset = m_id * s_loc
    if scales:
        from repro.models.transformer import dequantize_kv, quantize_kv
        ks, vs = scales
        nk_q, nk_s = quantize_kv(nk[:, 0])
        nv_q, nv_s = quantize_kv(nv[:, 0])
        new_ck = _write_row(ck, nk_q, lengths, offset, s_loc)
        new_cv = _write_row(cv, nv_q, lengths, offset, s_loc)
        new_ks = _write_row(ks, nk_s, lengths, offset, s_loc)
        new_vs = _write_row(vs, nv_s, lengths, offset, s_loc)
        att_k = dequantize_kv(new_ck, new_ks, q.dtype)
        att_v = dequantize_kv(new_cv, new_vs, q.dtype)
        extra = (new_ks, new_vs)
    else:
        new_ck = _write_row(ck, nk[:, 0], lengths, offset, s_loc)
        new_cv = _write_row(cv, nv[:, 0], lengths, offset, s_loc)
        att_k, att_v = new_ck, new_cv
        extra = ()

    kv_pos = offset + jnp.arange(s_loc, dtype=jnp.int32)
    out, res = chunked_attention(
        q, att_k, att_v, causal=False,
        q_positions=lengths[:, None], kv_positions=kv_pos,
        kv_len=lengths + 1, softcap=softcap, chunk=chunk,
        return_residuals=True)

    # merge partials across the sequence shards (tiny payloads)
    o_all = jax.lax.all_gather(out, seq_axis)            # (M, B, 1, H, D)
    m_all = jax.lax.all_gather(res.m, seq_axis)          # (M, B, H, 1)
    l_all = jax.lax.all_gather(res.l, seq_axis)
    parts = [AttnResiduals(out=o_all[i], m=m_all[i], l=l_all[i])
             for i in range(o_all.shape[0])]
    merged = merge_lse(parts)                            # (B, 1, H, D)
    return (merged, new_ck, new_cv, *extra)


def seq_sharded_decode_attention(q, cache_k, cache_v, k_new, v_new, lengths,
                                 *, k_scale=None, v_scale=None,
                                 softcap: float = 0.0, chunk: int = 2048):
    """Distributed decode attention; falls back to local compute off-mesh.

    Args:
      q: (B, 1, H, D); cache_k/v: (B, S, K, D) sequence-sharded over the
      mesh axis bound to the logical ``kv_seq`` axis; k_new/v_new: (B,1,K,D);
      lengths: (B,) current cache fill (new row written at ``lengths``);
      k_scale/v_scale: (B, S, K) absmax scales when the cache is int8.
    Returns:
      (attn_out (B,1,H,D), new_k, new_v[, new_k_scale, new_v_scale])
    """
    mesh = current_mesh()
    rules = current_rules()
    seq_axis = None if rules is None else rules.rules.get("kv_seq")
    quant = k_scale is not None
    scales = (k_scale, v_scale) if quant else ()
    if mesh is None or seq_axis is None or not isinstance(seq_axis, str):
        # single-device / unsharded path
        S = cache_k.shape[1]
        if quant:
            from repro.models.transformer import dequantize_kv, quantize_kv
            nk_q, nk_s = quantize_kv(k_new[:, 0])
            nv_q, nv_s = quantize_kv(v_new[:, 0])
            nk = _write_row(cache_k, nk_q, lengths, 0, S)
            nv = _write_row(cache_v, nv_q, lengths, 0, S)
            ks2 = _write_row(k_scale, nk_s, lengths, 0, S)
            vs2 = _write_row(v_scale, nv_s, lengths, 0, S)
            att_k = dequantize_kv(nk, ks2, q.dtype)
            att_v = dequantize_kv(nv, vs2, q.dtype)
            extra = (ks2, vs2)
        else:
            nk = _write_row(cache_k, k_new[:, 0], lengths, 0, S)
            nv = _write_row(cache_v, v_new[:, 0], lengths, 0, S)
            att_k, att_v = nk, nv
            extra = ()
        out = chunked_attention(
            q, att_k, att_v, causal=False, q_positions=lengths[:, None],
            kv_positions=jnp.arange(S, dtype=jnp.int32),
            kv_len=lengths + 1, softcap=softcap, chunk=chunk)
        return (out, nk, nv, *extra)

    batch_axes = rules.rules.get("batch")
    qspec = P(batch_axes, None, None, None)
    cspec = P(batch_axes, seq_axis, None, None)
    sspec = P(batch_axes, seq_axis, None)
    lspec = P(batch_axes)
    body = partial(_local_decode, seq_axis=seq_axis, softcap=softcap,
                   chunk=chunk)
    in_specs = (qspec, cspec, cspec, qspec, qspec, lspec) + \
        ((sspec, sspec) if quant else ())
    out_specs = (qspec, cspec, cspec) + ((sspec, sspec) if quant else ())
    return jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(q, cache_k, cache_v, k_new, v_new, lengths, *scales)
