"""Elastic re-meshing: shrink the data axis on device loss and re-shard
state onto the surviving mesh.

Policy (1000+-node posture): the `model` (TP/EP) axis is sacred — losing a
chip inside a TP group kills the whole replica group, so recovery drops an
integer number of data-parallel rows and continues with a smaller global
batch (or the same batch via more grad-accum).  The pod axis behaves like
the data axis one level up.

On this container the "devices" are XLA host-platform placeholders, so the
re-shard is exercised with real device_puts in tests; on a real fleet the
same code runs after the cluster manager returns the surviving topology.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ShardingRules

Pytree = Any


def surviving_mesh(mesh: Mesh, lost_device_ids: set[int]) -> Mesh:
    """Rebuild the mesh without the data-rows containing lost devices.

    mesh.devices has shape (*outer, data, model) — we drop rows along the
    -2 (data) axis that contain any lost device.
    """
    devs = mesh.devices
    axis_names = mesh.axis_names
    data_axis = len(devs.shape) - 2
    keep_rows = []
    for i in range(devs.shape[data_axis]):
        row = np.take(devs, i, axis=data_axis)
        ids = {d.id for d in row.flatten()}
        if not (ids & lost_device_ids):
            keep_rows.append(i)
    if not keep_rows:
        raise RuntimeError("no surviving data rows — cannot re-mesh")
    new_devs = np.take(devs, keep_rows, axis=data_axis)
    return Mesh(new_devs, axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))


def reshard(tree: Pytree, axes_tree: Pytree, new_mesh: Mesh,
            rules: ShardingRules) -> Pytree:
    """Re-place every leaf onto the new mesh under the same logical axes."""
    def _is_axes_leaf(t):
        return (isinstance(t, tuple) and not hasattr(t, "_fields")
                and all(x is None or isinstance(x, (str, tuple)) for x in t))

    shardings = jax.tree_util.tree_map(
        lambda axes: NamedSharding(new_mesh, rules.spec(list(axes))),
        axes_tree, is_leaf=_is_axes_leaf)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings)


def shrink_batch(batch_size: int, old_rows: int, new_rows: int) -> int:
    """Largest batch divisible by the surviving data rows."""
    per = batch_size // old_rows
    return per * new_rows
