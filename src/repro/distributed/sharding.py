"""Logical-axis sharding: rules, constraints, and per-arch policies.

Model code annotates activations/params with *logical* axis names ("batch",
"heads", "embed", ...).  A :class:`ShardingRules` maps logical names to mesh
axes; policies in :func:`rules_for` pick the mapping per (arch x shape x mesh).

Outside a mesh/rules context every constraint is a no-op, so the same model
code runs in single-device tests and pod-scale dry-runs.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# Logical axis vocabulary (see DESIGN.md §4):
#   batch      activation batch dim
#   seq        activation sequence dim
#   kv_seq     KV-cache sequence dim (context parallelism during decode)
#   embed      model dim of params (FSDP shard axis)
#   embed_act  model dim of activations (sequence-parallel regions only)
#   heads      attention query heads (TP)
#   kv_heads   attention KV heads (TP when divisible, else replicated)
#   ff         feed-forward hidden (TP)
#   vocab      vocabulary dim (TP)
#   experts    MoE expert dim (EP)
#   ff_expert  per-expert hidden dim
#   layers     stacked-layer scan dim (never sharded)
#   state      SSM/xLSTM recurrent state dims (never sharded)
#   conv       conv kernel spatial dims (never sharded)

Axis = Any  # str | tuple[str, ...] | None


@dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, Axis] = field(default_factory=dict)

    def spec(self, axes: Sequence[str | None]) -> P:
        parts = []
        for ax in axes:
            if ax is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(ax))
        # Trim trailing Nones for tidier specs.
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


_STATE = threading.local()


def current_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


def current_mesh() -> Mesh | None:
    m = getattr(_STATE, "mesh", None)
    if m is not None:
        return m
    # Fall back to an ambient `with mesh:` context if one is active.
    env = jax._src.mesh.thread_resources.env  # noqa: SLF001
    return env.physical_mesh if not env.physical_mesh.empty else None


@contextlib.contextmanager
def use_rules(rules: ShardingRules, mesh: Mesh | None = None):
    prev_r = getattr(_STATE, "rules", None)
    prev_m = getattr(_STATE, "mesh", None)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield rules
    finally:
        _STATE.rules, _STATE.mesh = prev_r, prev_m


def logical_to_spec(axes: Sequence[str | None],
                    rules: ShardingRules | None = None) -> P:
    rules = rules or current_rules()
    if rules is None:
        return P()
    return rules.spec(axes)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op without active rules/mesh."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(axes)
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, axes: Sequence[str | None],
                   rules: ShardingRules | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules))


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def param_count(cfg: ModelConfig) -> int:
    """Closed-form parameter-count estimate used for policy decisions."""
    d, L = cfg.d_model, cfg.num_layers
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.moe is not None:
        m = cfg.moe
        routed = m.num_experts * 3 * d * m.d_ff_expert
        shared = m.num_shared_experts * 3 * d * m.d_ff_shared
        router = d * m.num_experts
        moe_layers = L - m.first_k_dense
        ffn = moe_layers * (routed + shared + router)
        ffn += m.first_k_dense * 3 * d * (m.d_ff_dense or cfg.d_ff)
        ffn_per_layer = 0
    else:
        ffn_per_layer = 3 * d * cfg.d_ff
        ffn = L * ffn_per_layer
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return L * attn + ffn + embed


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top-k + shared experts count)."""
    if cfg.moe is None:
        return param_count(cfg)
    d, L, m = cfg.d_model, cfg.num_layers, cfg.moe
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    routed = m.top_k * 3 * d * m.d_ff_expert
    shared = m.num_shared_experts * 3 * d * m.d_ff_shared
    moe_layers = L - m.first_k_dense
    ffn = moe_layers * (routed + shared + d * m.num_experts)
    ffn += m.first_k_dense * 3 * d * (m.d_ff_dense or cfg.d_ff)
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return L * attn + ffn + embed


# Models above this size get FSDP (params sharded on the data axis too).
FSDP_THRESHOLD_PARAMS = 8e9


def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              *, fsdp: bool | None = None,
              seq_shard_kv: bool | None = None) -> ShardingRules:
    """Pick the sharding policy for one (arch x shape x mesh) cell."""
    model_sz = _mesh_axis_size(mesh, "model")
    data_sz = _mesh_axis_size(mesh, "data")
    pod_sz = _mesh_axis_size(mesh, "pod")
    has_pod = "pod" in mesh.axis_names

    n_params = param_count(cfg)
    if fsdp is None:
        fsdp = n_params >= FSDP_THRESHOLD_PARAMS and shape.kind == "train"
        # Serving giant models: weights must still be spread beyond TP to fit
        # (bf16 serving params; keep per-chip weight share under ~2 GB).
        if shape.kind != "train":
            fsdp = n_params * 2 / (model_sz or 1) > 2e9
    if seq_shard_kv is None:
        # Context-parallel KV cache: decode runs the LSE-merge shard_map path;
        # prefill lays its returned cache out the same way so the decode step
        # can consume it without a reshard.
        seq_shard_kv = shape.kind in ("decode", "prefill")

    batch_axes: Axis = ("pod", "data") if has_pod else ("data",)
    dp_total = data_sz * (pod_sz if has_pod else 1)
    if shape.global_batch % dp_total != 0 or shape.global_batch < dp_total:
        # e.g. long_500k batch=1: replicate batch rather than pad.
        batch_axes = None

    heads_axis: Axis = "model" if cfg.num_heads % max(model_sz, 1) == 0 else None
    kv_heads_axis: Axis = "model" if cfg.num_kv_heads % max(model_sz, 1) == 0 else None
    # Odd vocabularies (e.g. whisper's 51865) cannot shard across the model
    # axis; replicate the embedding/LM head instead of padding the table.
    vocab_axis: Axis = "model" if cfg.vocab_size % max(model_sz, 1) == 0 else None

    rules: dict[str, Axis] = {
        "batch": batch_axes,
        "seq": None,
        # MoE dispatch region: sequence sharded over the model axis so every
        # device owns a disjoint token slice before the EP all-to-all.
        "seq_model": "model",
        # Sequence-parallel residual stream (training): the scan-carried
        # activations between blocks shard their seq dim over the model axis,
        # cutting saved-carry memory by |model|; XLA turns the TP all-reduce
        # at block exit into reduce-scatter + all-gather (same bytes).
        "seq_sp": "model" if (shape.kind == "train"
                              and shape.seq_len % max(model_sz, 1) == 0)
                  else None,
        "kv_seq": "model" if seq_shard_kv else None,
        "embed": "data" if fsdp else None,
        "embed_act": None,
        "heads": heads_axis,
        "kv_heads": kv_heads_axis,
        "ff": "model",
        "vocab": vocab_axis,
        "experts": "model",
        "ff_expert": None,
        "layers": None,
        "state": None,
        "conv": None,
    }
    if cfg.moe is not None:
        # EP owns the model axis for expert weights; dense-part TP unchanged.
        rules["ff_expert"] = None
    # When decode KV is sequence-sharded, attention runs distributed over
    # kv_seq; KV heads stay local to avoid double-sharding the cache.
    if seq_shard_kv:
        rules["kv_heads"] = None
    return ShardingRules(rules)
