"""Sharding policy glue: NamedShardings for every pytree a step touches.

Builds, per (arch x shape x mesh) cell: parameter shardings (from the param
tables' logical axes), optimizer-state shardings (derived by the optimizer
from param axes), decode-state shardings (per family), and input-batch
shardings.  This is the one place the dry-run, trainer, and serving launcher
get their in/out_shardings from.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules, rules_for
from repro.models import encdec, hybrid, recurrent, transformer
from repro.models.layers.module import axes_of
from repro.models.registry import fns_for


def _is_axes_leaf(t) -> bool:
    """Plain tuple of axis names (NamedTuples are containers, not leaves)."""
    return (isinstance(t, tuple) and not hasattr(t, "_fields")
            and all(x is None or isinstance(x, (str, tuple)) for x in t))


def _to_shardings(axes_tree, mesh: Mesh, rules: ShardingRules):
    def conv(axes):
        return NamedSharding(mesh, rules.spec(list(axes)))
    return jax.tree_util.tree_map(conv, axes_tree, is_leaf=_is_axes_leaf)


def param_axes(cfg: ModelConfig):
    return axes_of(fns_for(cfg).table(cfg))


def param_shardings(cfg, mesh, rules):
    return _to_shardings(param_axes(cfg), mesh, rules)


def opt_state_shardings(cfg, optimizer, mesh, rules):
    return _to_shardings(optimizer.state_axes(param_axes(cfg)), mesh, rules)


# --- decode state -----------------------------------------------------------

def decode_state_axes(cfg: ModelConfig, cache_dtype: str = "bfloat16"):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cache_dtype == "int8":
            return transformer.QuantKVCache(
                k=("layers", "batch", "kv_seq", "kv_heads", None),
                v=("layers", "batch", "kv_seq", "kv_heads", None),
                k_scale=("layers", "batch", "kv_seq", "kv_heads"),
                v_scale=("layers", "batch", "kv_seq", "kv_heads"),
                length=("batch",))
        return transformer.KVCache(
            k=("layers", "batch", "kv_seq", "kv_heads", None),
            v=("layers", "batch", "kv_seq", "kv_heads", None),
            length=("batch",))
    if fam == "hybrid":
        return hybrid.HybridState(
            conv_seg=(None, None, "batch", None, "ff"),
            ssm_seg=(None, None, "batch", "heads", None, None),
            conv_tail=(None, "batch", None, "ff"),
            ssm_tail=(None, "batch", "heads", None, None),
            kv_k=(None, "batch", "kv_seq", "kv_heads", None),
            kv_v=(None, "batch", "kv_seq", "kv_heads", None),
            length=("batch",))
    if fam == "ssm":
        from repro.models.layers.xlstm import MLSTMState, SLSTMState
        states = []
        for i in range(cfg.num_layers):
            if i % cfg.xlstm.slstm_every == 1:
                states.append(SLSTMState(h=("batch", None), c=("batch", None),
                                         n=("batch", None), m=("batch", None)))
            else:
                states.append(MLSTMState(conv=("batch", None, "ff"),
                                         mem=("batch", "heads", None, None)))
        return {"states": states, "length": ("batch",)}
    if fam == "audio":
        return encdec.EncDecState(
            self_k=("layers", "batch", "kv_seq", "kv_heads", None),
            self_v=("layers", "batch", "kv_seq", "kv_heads", None),
            cross_k=("layers", "batch", None, "kv_heads", None),
            cross_v=("layers", "batch", None, "kv_heads", None),
            length=("batch",))
    raise ValueError(fam)


def decode_state_shardings(cfg, mesh, rules, cache_dtype: str = "bfloat16"):
    return _to_shardings(decode_state_axes(cfg, cache_dtype), mesh, rules)


# --- inputs -------------------------------------------------------------------

def batch_axes_for(name: str, ndim: int):
    if name == "positions":
        return (None, "batch", "seq")
    if name == "frames":
        return ("batch", None, None)
    if name == "images":
        return ("batch", None, None, None)
    if ndim == 1:
        return ("batch",)
    return ("batch", "seq")[:ndim] if ndim <= 2 else \
        ("batch",) + (None,) * (ndim - 1)


def batch_shardings(batch_specs: dict, mesh, rules):
    return {k: NamedSharding(mesh, rules.spec(list(batch_axes_for(k, v.ndim))))
            for k, v in batch_specs.items()}


def sharded_bytes_per_device(sds_tree, shardings_tree, mesh: Mesh) -> int:
    """Exact per-device bytes of a pytree under NamedShardings (analytic —
    not subject to the CPU backend's bf16->f32 legalization inflation)."""
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(sds, sh) -> int:
        n = int(np.prod(sds.shape)) if sds.shape else 1
        n *= np.dtype(sds.dtype).itemsize
        denom = 1
        for entry in sh.spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= sizes.get(ax, 1)
        return -(-n // denom)

    leaves_s = jax.tree_util.tree_leaves(sds_tree)
    leaves_h = jax.tree_util.tree_leaves(
        shardings_tree, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(leaves_s) == len(leaves_h), (len(leaves_s), len(leaves_h))
    return sum(leaf_bytes(s, h) for s, h in zip(leaves_s, leaves_h))


# --- cell bundle ----------------------------------------------------------------

def cell_policy(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, **overrides):
    """Everything the dry-run / launcher needs for one cell."""
    rules = rules_for(cfg, shape, mesh, **overrides)
    return rules
