"""Fault-tolerance runtime pieces: simulated failures, heartbeats, retry.

On real multi-host TPU fleets, node failure surfaces as a collective timeout
or a missing heartbeat; this container is single-process, so faults are
*injected* deterministically (by step) and the trainer must demonstrate the
recovery path: abort step -> restore from last committed checkpoint ->
(optionally) re-mesh elastically -> continue.  The same hooks are where a
real deployment would plug its cluster-manager callbacks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Mapping


class SimulatedFault(RuntimeError):
    """A node/device failure injected by the fault schedule."""

    def __init__(self, step: int, kind: str, detail: str = ""):
        super().__init__(f"simulated {kind} at step {step} {detail}")
        self.step = step
        self.kind = kind


@dataclass
class FaultSchedule:
    """step -> kind; kinds: 'crash' (lose state, restart from checkpoint),
    'device_loss' (elastic re-mesh), 'straggler' (inject delay seconds)."""

    events: Mapping[int, str] = field(default_factory=dict)
    straggler_delay: float = 0.05
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        kind = self.events.get(step)
        if kind is None or step in self._fired:
            return
        self._fired.add(step)
        if kind == "straggler":
            time.sleep(self.straggler_delay)
            return
        raise SimulatedFault(step, kind)


@dataclass
class Heartbeat:
    """Deadline-based liveness check.  `beat()` every step; `stalled()` is
    what a controller would poll to decide reissue/evict (paper's analogue:
    the NCSw host thread noticing a stuck NCS device)."""

    timeout_s: float = 30.0
    _last: float = field(default_factory=time.monotonic)

    def beat(self) -> None:
        self._last = time.monotonic()

    def stalled(self) -> bool:
        return (time.monotonic() - self._last) > self.timeout_s


def with_retries(fn: Callable, *, attempts: int = 3,
                 on_fault: Callable[[SimulatedFault, int], None] | None = None):
    """Run ``fn()``, retrying after SimulatedFault up to ``attempts`` times.
    ``on_fault(fault, attempt)`` performs recovery (restore/re-mesh)."""
    last: SimulatedFault | None = None
    for attempt in range(attempts):
        try:
            return fn()
        except SimulatedFault as f:
            last = f
            if on_fault is not None:
                on_fault(f, attempt)
    raise RuntimeError(f"exhausted {attempts} retries") from last
