from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    constrain,
    current_rules,
    logical_to_spec,
    rules_for,
    use_rules,
)
