"""Implicit-GEMM 2-D convolution for the GoogLeNet hot-spot — Pallas TPU.

GoogLeNet feature maps are small (<= 56x56 after the stem, <= 2.5 MiB fp32
per image including halos), so the whole padded map is staged into VMEM
once per (image, C_out block) and the K_h x K_w spatial taps unroll into
shifted (H*W, C_in) x (C_in, bc) GEMMs on the MXU — im2col without ever
materializing patches in HBM.  This mirrors what the paper's SIPP + SHAVE
pipeline does with 5x5 line buffers in the 2 MB CMX, scaled to VMEM sizes.

Oracle: `models.layers.conv.conv2d` (XLA conv_general_dilated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                 stride: int, hout: int, wout: int):
    cin = x_ref.shape[3]
    acc = jnp.zeros((hout * wout, o_ref.shape[3]), jnp.float32)
    x = x_ref[0]                                          # (Hp, Wp, Cin)
    for i in range(kh):
        for j in range(kw):
            xs = jax.lax.slice(
                x, (i, j, 0),
                (i + (hout - 1) * stride + 1, j + (wout - 1) * stride + 1,
                 cin),
                (stride, stride, 1))                      # (hout, wout, Cin)
            acc += jax.lax.dot_general(
                xs.reshape(hout * wout, cin), w_ref[i, j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    acc += b_ref[...].astype(jnp.float32)[None, :]
    o_ref[0] = acc.reshape(hout, wout, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "bc", "interpret"))
def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
           bc: int = 128, interpret: bool = False) -> jax.Array:
    """SAME conv. x: (B, H, W, Cin); w: (KH, KW, Cin, Cout); b: (Cout,)."""
    B, H, W, Cin = x.shape
    KH, KW, _, Cout = w.shape
    hout = -(-H // stride)
    wout = -(-W // stride)
    pad_h = max((hout - 1) * stride + KH - H, 0)
    pad_w = max((wout - 1) * stride + KW - W, 0)
    xp = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    Hp, Wp = xp.shape[1], xp.shape[2]
    bc = min(bc, Cout)
    assert Cout % bc == 0, (Cout, bc)
    return pl.pallas_call(
        functools.partial(_conv_kernel, kh=KH, kw=KW, stride=stride,
                          hout=hout, wout=wout),
        grid=(B, Cout // bc),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cin), lambda n, c: (n, 0, 0, 0)),
            pl.BlockSpec((KH, KW, Cin, bc), lambda n, c: (0, 0, 0, c)),
            pl.BlockSpec((bc,), lambda n, c: (c,)),
        ],
        out_specs=pl.BlockSpec((1, hout, wout, bc),
                               lambda n, c: (n, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, hout, wout, Cout), x.dtype),
        interpret=interpret,
    )(xp, w, b)
