"""Jit'd wrapper with backend dispatch for the conv2d kernel."""
from __future__ import annotations

import jax

from repro.kernels.conv2d.kernel import conv2d as _pallas
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.dispatch import register_kernel, use_pallas

register_kernel("conv2d", _pallas, conv2d_ref)


def conv2d(x, w, b, *, stride: int = 1, **block_kw):
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas(x, w, b, stride=stride, interpret=interpret,
                       **block_kw)
    return conv2d_ref(x, w, b, stride=stride)
