"""Oracle: XLA conv (same math as models.layers.conv.conv2d)."""
from __future__ import annotations

import jax


def conv2d_ref(x, w, b, *, stride: int = 1):
    out = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b.astype(x.dtype)
