"""Jit'd wrapper with backend dispatch for paged prefill attention."""
from __future__ import annotations

import jax

from repro.kernels.dispatch import register_kernel, use_pallas
from repro.kernels.prefill_attention.kernel import \
    paged_prefill_attention as _pallas_prefill
from repro.kernels.prefill_attention.ref import paged_prefill_attention_ref

register_kernel("paged_prefill_attention", _pallas_prefill,
                paged_prefill_attention_ref)


def paged_prefill_attention(q, k_pool, v_pool, block_tables, q_start,
                            lengths, *, k_scale=None, v_scale=None,
                            softcap: float = 0.0, chunk: int = 1024):
    """Prompt-chunk attention over a block pool + per-sequence tables.

    The cache-seeded prefill path calls this per layer after writing the
    chunk's KV rows into the pool; on TPU it lowers to the Pallas
    gather-by-block-table kernel, elsewhere to the jnp oracle — both
    causal against absolute positions so already-seeded blocks (shared
    prefixes, resumed histories) are attended without being recomputed.
    """
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas_prefill(q, k_pool, v_pool, block_tables, q_start,
                               lengths, k_scale=k_scale, v_scale=v_scale,
                               softcap=softcap, interpret=interpret)
    return paged_prefill_attention_ref(q, k_pool, v_pool, block_tables,
                                       q_start, lengths, k_scale=k_scale,
                                       v_scale=v_scale, softcap=softcap,
                                       chunk=chunk)
