"""Oracle for paged prefill attention: gather pool blocks by block table,
then causal chunked attention with the query chunk offset to ``q_start``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.attention import chunked_attention


def paged_prefill_attention_ref(q, k_pool, v_pool, block_tables, q_start,
                                lengths, *, k_scale=None, v_scale=None,
                                softcap=0.0, chunk=1024):
    """Multi-row query chunk vs block-table-gathered pool KV.

    q: (B, C, H, D) — a prompt chunk whose row ``o`` sits at absolute
    position ``q_start[b] + o``; k_pool/v_pool: (N, bs, K, D) global pool;
    block_tables: (B, max_blocks) physical block per logical block;
    q_start: (B,) first query position; lengths: (B,) total valid KV rows
    *including* this chunk's (the chunk's own rows are already written to
    the pool before attending).  k_scale/v_scale: (N, bs, K) for int8
    pools (absmax-dequantized before attending, matching the decode path).

    Causality makes row ``o`` attend to every seeded/earlier row plus the
    chunk rows at or before it; table entries past ``lengths`` (trash or
    spare decode blocks) sit at higher kv positions and are masked out.
    Returns (B, C, H, D).
    """
    B, C, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    mb = block_tables.shape[1]
    k = k_pool[block_tables]                     # (B, mb, bs, K, D)
    v = v_pool[block_tables]
    if k_scale is not None:
        k = (k.astype(jnp.float32)
             * k_scale[block_tables][..., None]).astype(q.dtype)
        v = (v.astype(jnp.float32)
             * v_scale[block_tables][..., None]).astype(q.dtype)
    S = mb * bs
    k = k.reshape(B, S, K, D).astype(q.dtype)
    v = v.reshape(B, S, K, D).astype(q.dtype)
    q_pos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    out = chunked_attention(
        q, k, v, causal=True, q_positions=q_pos,
        kv_positions=jnp.arange(S, dtype=jnp.int32),
        kv_len=lengths, softcap=softcap, chunk=chunk)
    return out
