"""Paged prefill attention — Pallas TPU.

The cache-seeded prefill path's kernel: a multi-row query chunk (C prompt
tokens starting at absolute position ``q_start``) attends over KV that
lives in the global block pool, addressed through a per-sequence block
table.  This is the multi-row sibling of `decode_attention`'s paged
kernel: same grid layout (B, K_heads, max_blocks), same scalar-prefetched
block table driving the k/v BlockSpec index map (DMA gathers exactly the
live blocks), same online-softmax scratch — but the query block is the
whole chunk, and the mask is *causal against absolute positions*, so the
chunk attends fully over already-seeded blocks (shared prefixes, resumed
histories) and triangularly within itself.  Blocks entirely past the
valid length are skipped with `pl.when`; int8 pools are dequantized
in-VMEM from per-row absmax scales.

Oracle: `ref.paged_prefill_attention_ref` (gather + chunked attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(bt_ref, qs_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                    scale: float, bs: int, mb: int, G: int, softcap: float,
                    quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = len_ref[b]
    start = qs_ref[b]

    # Blocks wholly past the valid rows (trash entries, spare decode
    # blocks) are never even DMA'd into the accumulation.
    @pl.when(ib * bs < valid)
    def _update():
        q = q_ref[0, 0, :, :]                     # (C*G, D)
        k = k_ref[0, :, 0, :]                     # (bs, D)
        v = v_ref[0, :, 0, :]
        if quant:
            k = (k.astype(jnp.float32)
                 * ks_ref[0, :, 0][:, None]).astype(q.dtype)
            v = (v.astype(jnp.float32)
                 * vs_ref[0, :, 0][:, None]).astype(q.dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        # row r of the (C*G, bs) score tile is query offset r // G; causal
        # against absolute positions lets the chunk see every seeded row
        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((k_pos <= q_pos) & (k_pos < valid), s, NEG_INF)

        m_prev = m_ref[...]                       # (C*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ib == mb - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            q_start: jax.Array, lengths: jax.Array, *,
                            k_scale: jax.Array | None = None,
                            v_scale: jax.Array | None = None,
                            softcap: float = 0.0,
                            interpret: bool = False) -> jax.Array:
    """q: (B, C, H, D) query chunk at positions ``q_start .. q_start+C-1``;
    k_pool/v_pool: (N, bs, K, D) global block pool; block_tables:
    (B, max_blocks); q_start: (B,) chunk origin; lengths: (B,) valid rows
    incl. the chunk; k_scale/v_scale: (N, bs, K) for int8 pools.

    Returns (B, C, H, D).  Grid (B, K, max_blocks); tables, q_start, and
    lengths are scalar-prefetch operands, so the k/v BlockSpec index maps
    DMA each sequence's physical blocks in logical order.
    """
    B, C, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    mb = block_tables.shape[1]
    G = H // K
    scale = 1.0 / (D ** 0.5)
    qg = (q.reshape(B, C, K, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, K, C * G, D))
    quant = k_scale is not None

    def q_map(b, h, ib, bt_ref, qs_ref, len_ref):
        return (b, h, 0, 0)

    def kv_map(b, h, ib, bt_ref, qs_ref, len_ref):
        return (bt_ref[b, ib], 0, h, 0)

    def sc_map(b, h, ib, bt_ref, qs_ref, len_ref):
        return (bt_ref[b, ib], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, C * G, D), q_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
    ]
    args = [qg, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), sc_map),
                     pl.BlockSpec((1, bs, 1), sc_map)]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, C * G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, 1), jnp.float32),
            pltpu.VMEM((C * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, bs=bs, mb=mb, G=G,
                          softcap=softcap, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, C * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), q_start.astype(jnp.int32),
      lengths.astype(jnp.int32), *args)
    return (out.reshape(B, K, C, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, C, H, D))
