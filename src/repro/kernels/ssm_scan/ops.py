"""Jit'd wrapper with backend dispatch for the SSD chunk scan."""
from __future__ import annotations

import jax

from repro.kernels.dispatch import register_kernel, use_pallas
from repro.kernels.ssm_scan.kernel import ssm_scan as _pallas
from repro.kernels.ssm_scan.ref import ssm_scan_ref

register_kernel("ssm_scan", _pallas, ssm_scan_ref)


def ssm_scan(q, k, v, log_decay, log_gate, *, chunk: int = 128):
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas(q, k, v, log_decay, log_gate, chunk=chunk,
                       interpret=interpret)
    return ssm_scan_ref(q, k, v, log_decay, log_gate, chunk=chunk)
