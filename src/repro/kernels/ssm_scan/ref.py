"""Oracle: the shared chunked linear-recurrence core."""
from repro.models.layers.ssm import chunked_linear_attn


def ssm_scan_ref(q, k, v, log_decay, log_gate, *, chunk=128):
    y, _ = chunked_linear_attn(q, k, v, log_decay, log_gate, chunk=chunk)
    return y
