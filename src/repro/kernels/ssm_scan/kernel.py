"""Chunked SSD / decayed linear-attention scan — Pallas TPU.

The shared recurrence behind Mamba-2 and mLSTM:

    H_t = exp(d_t) H_{t-1} + exp(g_t) k_t v_t^T ;  y_t = q_t . H_t

Grid (B, H, S/Q) with the chunk dimension innermost and sequential: the
(N, P) fp32 state lives in VMEM scratch across chunk steps (the TPU
analogue of keeping the working set resident in the Myriad's CMX between
SIPP stages).  Per chunk: intra-chunk quadratic part on the MXU + rank-Q
state update; cross-chunk recurrence is carried, never materialized to HBM.

Oracle: `models.layers.ssm.chunked_linear_attn`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(q_ref, k_ref, v_ref, d_ref, g_ref, o_ref, state_ref, *,
                chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)     # (Q, P)
    d = d_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    g = g_ref[0, :, 0].astype(jnp.float32)

    cum = jnp.cumsum(d)                           # (Q,)
    total = cum[-1]
    # intra-chunk: w[i,j] = exp(cum_i - cum_j + g_j), i >= j
    logw = cum[:, None] - cum[None, :] + g[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(causal, jnp.exp(jnp.minimum(logw, 30.0)), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * w, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    # inter-chunk: y_off = exp(cum_i) * q_i . H_prev
    h_prev = state_ref[...]                       # (N, P)
    y_off = jnp.exp(jnp.minimum(cum, 30.0))[:, None] * jax.lax.dot_general(
        q, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = (y_diag + y_off).astype(o_ref.dtype)
    # state update: H = exp(total) H + sum_j exp(total - cum_j + g_j) k_j v_j
    wk = jnp.exp(jnp.minimum(total - cum + g, 30.0))[:, None]      # (Q,1)
    s_c = jax.lax.dot_general(k * wk, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N,P)
    state_ref[...] = jnp.exp(jnp.minimum(total, 30.0)) * h_prev + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array,
             log_decay: jax.Array, log_gate: jax.Array, *,
             chunk: int = 128, interpret: bool = False) -> jax.Array:
    """q/k: (B, S, H, N); v: (B, S, H, P); log_decay/log_gate: (B, S, H).

    Returns y (B, S, H, P) fp32 (matching the oracle's accumulation dtype).
    """
    B, S, H, N = k.shape
    P = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)
    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_decay, log_gate)
