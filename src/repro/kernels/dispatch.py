"""Kernel backend dispatch.

Pallas kernels target TPU; on this CPU-only container they execute in
``interpret=True`` mode (Python evaluation of the kernel body), which is
correct but slow — so the model layers default to their jnp oracles and
kernels are opt-in (``enable_pallas()``), becoming the default on a real
TPU backend.
"""
from __future__ import annotations

import contextlib
import threading

_STATE = threading.local()


def use_pallas() -> bool:
    import jax
    forced = getattr(_STATE, "forced", None)
    if forced is not None:
        return forced
    return jax.default_backend() == "tpu"


def enable_pallas(on: bool = True) -> None:
    _STATE.forced = on


@contextlib.contextmanager
def pallas_enabled(on: bool = True):
    prev = getattr(_STATE, "forced", None)
    _STATE.forced = on
    try:
        yield
    finally:
        _STATE.forced = prev
