"""Kernel backend dispatch.

Pallas kernels target TPU; on this CPU-only container they execute in
``interpret=True`` mode (Python evaluation of the kernel body), which is
correct but slow — so the model layers default to their jnp oracles and
kernels are opt-in (``enable_pallas()``), becoming the default on a real
TPU backend.

Each kernel family's ops module registers its (pallas, ref) pair in the
kernel table via :func:`register_kernel` (backend selection itself lives
in the ops wrappers, which also own the interpret-mode fallback).
`benchmarks/kernel_bench.py --smoke` (a tier-1 CI gate) cross-checks the
table against its correctness cases — registering a kernel without a
smoke case fails the build, as does any kernel-vs-oracle mismatch.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, NamedTuple

_STATE = threading.local()


class KernelEntry(NamedTuple):
    pallas: Callable
    ref: Callable


_TABLE: dict[str, KernelEntry] = {}


def register_kernel(name: str, pallas_fn: Callable, ref_fn: Callable) -> None:
    """Register a kernel's Pallas implementation and its jnp oracle."""
    _TABLE[name] = KernelEntry(pallas_fn, ref_fn)


def kernel_table() -> dict[str, KernelEntry]:
    return dict(_TABLE)


def use_pallas() -> bool:
    import jax
    forced = getattr(_STATE, "forced", None)
    if forced is not None:
        return forced
    return jax.default_backend() == "tpu"


def enable_pallas(on: bool = True) -> None:
    _STATE.forced = on


@contextlib.contextmanager
def pallas_enabled(on: bool = True):
    prev = getattr(_STATE, "forced", None)
    _STATE.forced = on
    try:
        yield
    finally:
        _STATE.forced = prev
