"""Jit'd wrappers with backend dispatch for flash-decode (dense + paged)."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention as _pallas
from repro.kernels.decode_attention.kernel import \
    paged_decode_attention as _pallas_paged
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.dispatch import register_kernel, use_pallas

register_kernel("decode_attention", _pallas, decode_attention_ref)
register_kernel("paged_decode_attention", _pallas_paged,
                paged_decode_attention_ref)


def decode_attention(q, k, v, lengths, **block_kw):
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas(q, k, v, lengths, interpret=interpret, **block_kw)
    return decode_attention_ref(q, k, v, lengths)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           k_scale=None, v_scale=None, softcap: float = 0.0,
                           chunk: int = 1024):
    """Paged decode attention over a block pool + per-sequence block tables.

    The serving decode path calls this per layer; on TPU it lowers to the
    Pallas gather-by-block-table kernel, elsewhere to the jnp oracle
    (gather + chunked attention), bit-compatible with the dense path.
    """
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas_paged(q, k_pool, v_pool, block_tables, lengths,
                             k_scale=k_scale, v_scale=v_scale,
                             softcap=softcap, interpret=interpret)
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tables,
                                      lengths, k_scale=k_scale,
                                      v_scale=v_scale, softcap=softcap,
                                      chunk=chunk)
