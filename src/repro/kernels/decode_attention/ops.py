"""Jit'd wrapper with backend dispatch for flash-decode."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention as _pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.dispatch import use_pallas


def decode_attention(q, k, v, lengths, **block_kw):
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas(q, k, v, lengths, interpret=interpret, **block_kw)
    return decode_attention_ref(q, k, v, lengths)
