"""Oracles for flash-decode (dense and paged): chunked attention with
kv_len masking; the paged variant gathers pool blocks by block table."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.attention import chunked_attention


def decode_attention_ref(q, k, v, lengths, *, chunk=1024):
    """q: (B, H, D); k/v: (B, S, K, D); lengths: (B,)."""
    B, H, D = q.shape
    S = k.shape[1]
    out = chunked_attention(
        q[:, None], k, v, causal=False,
        q_positions=jnp.zeros((B, 1), jnp.int32),
        kv_positions=jnp.arange(S, dtype=jnp.int32),
        kv_len=lengths, chunk=chunk)
    return out[:, 0]


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               k_scale=None, v_scale=None, softcap=0.0,
                               chunk=1024):
    """Paged oracle: gather blocks into logical order, then dense decode.

    q: (B, H, D); k_pool/v_pool: (N, bs, K, D) global pool; block_tables:
    (B, max_blocks) physical block ids per logical block; lengths: (B,)
    valid rows per sequence.  k_scale/v_scale: (N, bs, K) when the pool is
    int8 (absmax-dequantized to q.dtype before attending, matching the
    dense quantized-cache path bit for bit).
    """
    B, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    mb = block_tables.shape[1]
    k = k_pool[block_tables]                     # (B, mb, bs, K, D)
    v = v_pool[block_tables]
    if k_scale is not None:
        k = (k.astype(jnp.float32)
             * k_scale[block_tables][..., None]).astype(q.dtype)
        v = (v.astype(jnp.float32)
             * v_scale[block_tables][..., None]).astype(q.dtype)
    S = mb * bs
    k = k.reshape(B, S, K, D).astype(q.dtype)
    v = v.reshape(B, S, K, D).astype(q.dtype)
    out = chunked_attention(
        q[:, None], k, v, causal=False,
        q_positions=jnp.zeros((B, 1), jnp.int32),
        kv_positions=jnp.arange(S, dtype=jnp.int32),
        kv_len=lengths, softcap=softcap, chunk=chunk)
    return out[:, 0]
