"""Oracle for flash-decode: chunked attention with kv_len masking."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.attention import chunked_attention


def decode_attention_ref(q, k, v, lengths, *, chunk=1024):
    """q: (B, H, D); k/v: (B, S, K, D); lengths: (B,)."""
    B, H, D = q.shape
    S = k.shape[1]
    out = chunked_attention(
        q[:, None], k, v, causal=False,
        q_positions=jnp.zeros((B, 1), jnp.int32),
        kv_positions=jnp.arange(S, dtype=jnp.int32),
        kv_len=lengths, chunk=chunk)
    return out[:, 0]
