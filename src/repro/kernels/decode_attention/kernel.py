"""Flash-decode: one query token vs a long KV cache — Pallas TPU.

Grid (B, K_heads, S/bkv): for each (batch, kv-head) the G grouped query
heads attend to KV blocks streamed through VMEM; running (m, l, acc) live
in scratch, per-sequence valid length masks dead slots.  This is the
split-K decode kernel whose distributed twin is the LSE-merge path in
`distributed.collectives` (the per-shard partials there are exactly this
kernel's (out, m, l) triple).

`paged_decode_attention` is the paged variant: the KV lives in a global
pool of fixed-size blocks and each sequence's block table is a
scalar-prefetch input, so the BlockSpec index map gathers exactly the
sequence's live blocks from HBM — decode traffic scales with actual
sequence length, not the worst-case ``max_len``.  Blocks past ``length``
are skipped outright (`pl.when`), and an int8 pool is dequantized in-VMEM
from per-row absmax scales.

Oracle: `models.layers.attention.chunked_attention` with kv_len masking
(`ref.decode_attention_ref` / `ref.paged_decode_attention_ref`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bkv: int, n_kv: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :]                         # (G, D)
    k = k_ref[0, :, 0, :]                         # (bkv, D)
    v = v_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = len_ref[0, 0]
    k_pos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < valid, s, NEG_INF)

    m_prev = m_ref[...]                           # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    p = jnp.exp(s - m_safe)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, bkv: int = 1024,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k/v: (B, S, K, D); lengths: (B,) valid KV per sequence.

    Returns (B, H, D).
    """
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    bkv = min(bkv, S)
    assert S % bkv == 0, (S, bkv)
    n_kv = S // bkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, K, G, D)
    len2d = lengths.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bkv=bkv, n_kv=n_kv),
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ik: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bkv, 1, D), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(len2d, qg, k, v)
    return out.reshape(B, H, D)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest, scale: float,
                  bs: int, mb: int, softcap: float, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = len_ref[b]

    # Dead logical blocks (table entry -> trash block 0) are skipped: the
    # kernel's read traffic follows the live length, not the table width.
    @pl.when(ib * bs < valid)
    def _update():
        q = q_ref[0, 0, :, :]                     # (G, D)
        k = k_ref[0, :, 0, :]                     # (bs, D)
        v = v_ref[0, :, 0, :]
        if quant:
            k = (k.astype(jnp.float32)
                 * ks_ref[0, :, 0][:, None]).astype(q.dtype)
            v = (v.astype(jnp.float32)
                 * vs_ref[0, :, 0][:, None]).astype(q.dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < valid, s, NEG_INF)

        m_prev = m_ref[...]                       # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ib == mb - 1)
    def _finalize():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array, lengths: jax.Array, *,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           softcap: float = 0.0,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_pool/v_pool: (N, bs, K, D) global block pool;
    block_tables: (B, max_blocks) physical block per logical block;
    lengths: (B,) valid rows; k_scale/v_scale: (N, bs, K) for int8 pools.

    Returns (B, H, D).  Grid (B, K, max_blocks); the block table is a
    scalar-prefetch operand so the k/v BlockSpec index maps dereference it
    to DMA each sequence's physical blocks in logical order.
    """
    B, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    mb = block_tables.shape[1]
    G = H // K
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, K, G, D)
    quant = k_scale is not None

    def q_map(b, h, ib, bt_ref, len_ref):
        return (b, h, 0, 0)

    def kv_map(b, h, ib, bt_ref, len_ref):
        return (bt_ref[b, ib], 0, h, 0)

    def sc_map(b, h, ib, bt_ref, len_ref):
        return (bt_ref[b, ib], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, D), q_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
    ]
    args = [qg, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), sc_map),
                     pl.BlockSpec((1, bs, 1), sc_map)]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, bs=bs, mb=mb,
                          softcap=softcap, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *args)
    return out.reshape(B, H, D)
