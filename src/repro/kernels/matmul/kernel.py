"""Block GEMM with explicit VMEM tiling — the TPU adaptation of the paper's
cited Ionica et al. Myriad-1 DGEMM (CMX tiles -> VMEM tiles, SHAVE VLIW
lanes -> MXU 128x128 systolic array).

Tiling: grid (M/bm, N/bn, K/bk); K is the innermost ("arbitrary") dimension
so each (i, j) output tile accumulates over K in an fp32 VMEM scratch and
writes once.  Default 512^3 blocks = 3 MiB fp32 working set per step —
small against the ~128 MiB/core VMEM, MXU-aligned (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 512, bn: int = 512,
           bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K) @ y: (K, N) -> (M, N); fp32 accumulation in VMEM."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
