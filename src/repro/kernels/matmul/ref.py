"""Pure-jnp oracle for the block GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(x.dtype)
