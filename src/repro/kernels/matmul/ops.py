"""Jit'd public wrapper with backend dispatch for the block GEMM."""
from __future__ import annotations

import jax

from repro.kernels.dispatch import register_kernel, use_pallas
from repro.kernels.matmul.kernel import matmul as matmul_pallas
from repro.kernels.matmul.ref import matmul_ref

register_kernel("matmul", matmul_pallas, matmul_ref)


def matmul(x: jax.Array, y: jax.Array, **block_kw) -> jax.Array:
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return matmul_pallas(x, y, interpret=interpret, **block_kw)
    return matmul_ref(x, y)
