"""Jit'd wrapper with backend dispatch for prefill flash attention."""
from __future__ import annotations

import jax

from repro.kernels.dispatch import register_kernel, use_pallas
from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import flash_attention_ref

register_kernel("flash_attention", _pallas, flash_attention_ref)


def flash_attention(q, k, v, *, causal: bool = True, **block_kw):
    if use_pallas():
        interpret = jax.default_backend() != "tpu"
        return _pallas(q, k, v, causal=causal, interpret=interpret,
                       **block_kw)
    return flash_attention_ref(q, k, v, causal=causal)
