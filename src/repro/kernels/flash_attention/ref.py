"""Oracle: the shared chunked online-softmax attention."""
from repro.models.layers.attention import chunked_attention


def flash_attention_ref(q, k, v, *, causal=True, chunk=512):
    return chunked_attention(q, k, v, causal=causal, chunk=chunk)
