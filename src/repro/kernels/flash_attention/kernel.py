"""Fused causal GQA flash attention (prefill) — Pallas TPU.

Grid (B, H, S/bq, S/bkv), KV innermost; online-softmax running stats
(m, l) and the fp32 accumulator live in VMEM scratch across KV steps.
Blocks entirely above the causal diagonal are skipped with `pl.when`
(halving prefill work); the diagonal block is masked elementwise.

Default blocks bq=bkv=512, D=128: working set q(512x128x4) + k/v + acc
~ 1 MiB — sized so one (q, kv) tile pair streams through the MXU while the
next KV tile prefetches from HBM.  The jnp oracle is
`models.layers.attention.chunked_attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, bq: int, bkv: int, n_kv: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _block():
        q = q_ref[0, :, 0, :]                     # (bq, D)
        k = k_ref[0, :, 0, :]                     # (bkv, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bkv), 0)
            k_pos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32,
                                                        (bq, bkv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]                       # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe)                   # (bq, bkv)
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)   # (bq, D)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(ik * bkv <= iq * bq + bq - 1)(_block)
    else:
        _block()

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 512, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, K, D), H % K == 0. Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    bq, bkv = min(bq, S), min(bkv, S)
    assert S % bq == 0 and S % bkv == 0, (S, bq, bkv)
    scale = 1.0 / (D ** 0.5)
    n_kv = S // bkv
    grid = (B, H, S // bq, n_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bkv=bkv,
                          n_kv=n_kv, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, iq, ik, _G=G: (b, ik, h // _G, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, iq, ik, _G=G: (b, ik, h // _G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
