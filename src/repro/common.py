"""Small shared utilities: dtype resolution, initializers, pytree helpers."""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int32": jnp.int32,
    "int8": jnp.int8,
}


def dtype_of(name: str | jnp.dtype) -> jnp.dtype:
    if isinstance(name, str):
        return _DTYPES[name]
    return name


def truncated_normal_init(key: jax.Array, shape: tuple[int, ...], dtype,
                          stddev: float | None = None,
                          fan_in_axis: int = -2) -> jax.Array:
    """Truncated-normal init with 1/sqrt(fan_in) default stddev."""
    if stddev is None:
        fan_in = shape[fan_in_axis] if len(shape) >= 2 else shape[0]
        stddev = 1.0 / np.sqrt(max(fan_in, 1))
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev
    return x.astype(dtype)


def zeros_init(key: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    del key
    return jnp.ones(shape, dtype)


def split_keys(key: jax.Array, names: Iterable[str]) -> Mapping[str, jax.Array]:
    names = list(names)
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def tree_size_bytes(tree: Pytree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize for l in leaves
               if hasattr(l, "shape"))


def tree_num_params(tree: Pytree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def cast_tree(tree: Pytree, dtype) -> Pytree:
    dt = dtype_of(dtype)

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dt)
        return x

    return jax.tree_util.tree_map(_cast, tree)


# jit-ok: host-side helper, never called under trace — pulls values to host
def assert_no_nans(tree: Pytree, where: str = "") -> None:
    """Host-side NaN check (tests/smoke only; pulls values to host)."""
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(~jnp.isfinite(leaf))):
                name = jax.tree_util.keystr(path)
                raise AssertionError(f"non-finite values at {where}{name}")


def shape_dtype(shape: tuple[int, ...], dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype_of(dtype))


def abstractify(tree: Pytree) -> Pytree:
    """Concrete pytree -> ShapeDtypeStruct pytree (for lowering)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
