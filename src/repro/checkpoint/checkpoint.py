"""NumPy-backed sharded checkpointer: per-host leaf files, atomic commit,
optional async save, retention, auto-resume.

Layout:
  <dir>/step_00000100/            (committed atomically via rename)
    MANIFEST.json                 {leaf path -> file, shape, dtype}
    p0000_<leaf>.npy              one file per pytree leaf per process
  <dir>/LATEST                    text file with the last committed step

Multi-host posture: every process writes only the leaves (or shards) it is
addressable for, under its process index; this container is single-process,
so files carry prefix ``p0000``.  Commit order (write tmp -> fsync -> rename
-> update LATEST) guarantees a crash never leaves a half checkpoint visible,
which is what the trainer's auto-resume relies on.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

Pytree = Any


def _leaf_name(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.]+", "_", s).strip("_")


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Pytree) -> None:
        """Snapshot to host memory synchronously; write to disk (maybe async)."""
        leaves = jax.tree_util.tree_leaves_with_path(tree)
        host = [(p, np.asarray(jax.device_get(l))) for p, l in leaves]
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host_leaves) -> None:
        proc = jax.process_index()
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + f".tmp{proc}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for path, arr in host_leaves:
            name = f"p{proc:04d}_{_leaf_name(path)}"
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest[_leaf_name(path)] = {
                "file": name + ".npy",
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f, indent=1)
        for fname in os.listdir(tmp):
            fd = os.open(os.path.join(tmp, fname), os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.directory, "LATEST.tmp"),
                   os.path.join(self.directory, "LATEST"))
        self._retain()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        p = os.path.join(self.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            step = int(f.read().strip())
        if os.path.isdir(os.path.join(self.directory, f"step_{step:08d}")):
            return step
        # fall back to the newest fully-committed directory
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Pytree) -> Pytree:
        """Restore into the structure of ``like`` (arrays or SDS stand-ins)."""
        self.wait()
        d = os.path.join(self.directory, f"step_{step:08d}")
        proc = jax.process_index()
        leaves = jax.tree_util.tree_leaves_with_path(like)
        out = []
        for path, leaf in leaves:
            name = f"p{proc:04d}_{_leaf_name(path)}.npy"
            arr = np.load(os.path.join(d, name))
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint shape mismatch at {_leaf_name(path)}: "
                    f"{arr.shape} vs {leaf.shape}")
            out.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Pytree) -> tuple[int, Pytree] | None:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, like)
