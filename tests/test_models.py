"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness; prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models.registry import fns_for
from repro.optim.optimizers import adamw, constant
from repro.training.train_step import make_train_step

ARCHS = list(R.ARCH_IDS)


def _batch(cfg, B, S, key=0, labels=True):
    rng = np.random.default_rng(key)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    d = {"tokens": jnp.asarray(toks)}
    if labels:
        d["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32))
    if cfg.m_rope:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        d["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.family == "audio":
        d["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encdec.num_encoder_frames, cfg.d_model), dtype=np.float32))
    return d


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = R.smoke(arch)
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    logits, aux = fns.forward(cfg, params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = R.smoke(arch)
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))
    step = jax.jit(make_train_step(cfg, opt, accum=1))
    new_params, opt_state, metrics = step(params, opt.init(params),
                                          _batch(cfg, 2, 16))
    assert np.isfinite(metrics["loss"])
    # parameters actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(lambda a, b: a.astype(jnp.float32)
                               - b.astype(jnp.float32), new_params, params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = R.smoke(arch)
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(1))
    B, S, extra = 2, 10, 3
    batch = _batch(cfg, B, S + extra, key=2, labels=False)
    full, _ = fns.forward(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S]
    if cfg.m_rope:
        pre["positions"] = batch["positions"][:, :, :S]
    lg, state = fns.prefill(cfg, params, pre, max_len=S + extra)
    np.testing.assert_allclose(lg, full[:, S - 1], atol=5e-2, rtol=1e-3)
    for t in range(S, S + extra):
        lg, state = fns.decode(cfg, params, batch["tokens"][:, t:t + 1],
                               state)
        np.testing.assert_allclose(lg, full[:, t], atol=5e-2, rtol=1e-3)


def test_train_accum_equivalence():
    """accum=2 must match accum=1 gradients (same global batch).

    Compared under a LINEAR update (SGD) — Adam's sign-sensitive normalized
    step would amplify float-reassociation noise into spurious diffs."""
    from repro.optim.optimizers import Optimizer

    def sgd(lr):
        def init(params):
            return {"step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            new_p = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, {"step": state["step"] + 1}, {}

        return Optimizer(init=init, update=update,
                         state_axes=lambda axes: {"step": ()})

    cfg = R.smoke("qwen2.5-3b")
    fns = fns_for(cfg)
    params = fns.init(cfg, jax.random.PRNGKey(0))
    opt = sgd(1.0)
    batch = _batch(cfg, 4, 8)
    s1 = jax.jit(make_train_step(cfg, opt, accum=1))
    s2 = jax.jit(make_train_step(cfg, opt, accum=2))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    # losses are bit-identical (forward is per-row independent)...
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=1e-6)
    # ...gradients agree to bf16 rounding (backward einsum outputs round to
    # bf16 once per microbatch grouping): bound by bf16 eps, and globally
    # by relative L2.
    num = den = 0.0
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        d = a.astype(jnp.float32) - b.astype(jnp.float32)
        np.testing.assert_allclose(a, b, atol=5e-2)
        num += float(jnp.sum(d * d))
        den += float(jnp.sum(jnp.square(a.astype(jnp.float32))))
    assert (num / den) ** 0.5 < 5e-3, (num / den) ** 0.5


def test_googlenet_forward_and_shapes():
    cfg = R.smoke("googlenet")
    from repro.models import googlenet
    params = googlenet.init(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    logits = googlenet.forward(cfg, params, imgs)
    assert logits.shape == (2, 1000)
    label, conf, probs = googlenet.predict(cfg, params, imgs)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-4)
