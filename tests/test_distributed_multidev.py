"""Multi-device paths (MoE EP, LSE-merge decode, compression, elastic,
mini dry-run) — run in SUBPROCESSES so the main pytest process keeps the
default single-device backend (the 512-device flag is dry-run-only)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_with_devices(code: str, n: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.xfail(
    strict=False,
    reason="pre-seed failure (one of the 4 known multidev failures tracked in\n"
           "ROADMAP, verified failing at seed commit 29cef53): the pinned jax\n"
           "lacks jax.sharding.AxisType")
def test_moe_ep_matches_dense():
    out = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.models.layers import moe as M
        from repro.distributed.sharding import ShardingRules, use_rules
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0)
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        params = {"router": jax.random.normal(ks[0], (16, 8)) * 0.1,
                  "w_gate": jax.random.normal(ks[1], (8, 16, 32)) * 0.1,
                  "w_up": jax.random.normal(ks[2], (8, 16, 32)) * 0.1,
                  "w_down": jax.random.normal(ks[3], (8, 32, 16)) * 0.1}
        x = jax.random.normal(ks[4], (2, 12, 16))
        idx, prob, _ = M.route(cfg, params, x)
        ref = M.moe_dense(cfg, params, x, idx, prob)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = ShardingRules({"batch": ("data",), "seq_model": "model",
                               "experts": "model", "embed_act": None,
                               "seq": None})
        with mesh, use_rules(rules, mesh):
            y = jax.jit(lambda *a: M.moe_apply(cfg, *a))(params, x, idx, prob)
        print(json.dumps({"err": float(jnp.abs(y - ref).max())}))
    """)
    assert out["err"] < 1e-5


@pytest.mark.xfail(
    strict=False,
    reason="pre-seed failure (one of the 4 known multidev failures tracked in\n"
           "ROADMAP, verified failing at seed commit 29cef53): the pinned jax\n"
           "lacks jax.sharding.AxisType")
def test_lse_merge_decode_matches_local():
    out = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.distributed.sharding import ShardingRules, use_rules
        from repro.distributed.collectives import seq_sharded_decode_attention
        B, S, H, K, D = 4, 32, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        ck = jax.random.normal(ks[1], (B, S, K, D))
        cv = jax.random.normal(ks[2], (B, S, K, D))
        nk = jax.random.normal(ks[3], (B, 1, K, D))
        nv = jax.random.normal(ks[4], (B, 1, K, D))
        lengths = jnp.array([5, 17, 31, 24], jnp.int32)
        ref, rk, rv = seq_sharded_decode_attention(q, ck, cv, nk, nv, lengths)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = ShardingRules({"batch": ("data",), "kv_seq": "model"})
        with mesh, use_rules(rules, mesh):
            o, k2, v2 = jax.jit(
                lambda *a: seq_sharded_decode_attention(*a))(
                q, ck, cv, nk, nv, lengths)
        print(json.dumps({
            "out": float(jnp.abs(o - ref).max()),
            "k": float(jnp.abs(k2 - rk).max()),
        }))
    """)
    assert out["out"] < 1e-5 and out["k"] == 0.0


@pytest.mark.xfail(
    strict=False,
    reason="pre-seed failure (one of the 4 known multidev failures tracked in\n"
           "ROADMAP, verified failing at seed commit 29cef53): the pinned jax\n"
           "lacks jax.sharding.AxisType")
def test_mini_dryrun_smoke_cell():
    """Lower+compile a smoke train step on an 8-device (2,4) mesh; verify
    memory analysis exists and collectives appear in the HLO."""
    out = run_with_devices("""
        import json, jax
        from repro.configs import registry as R
        from repro.configs.base import ShapeConfig
        from repro.configs.specs import abstract_params, input_specs
        from repro.distributed import policy
        from repro.distributed.sharding import rules_for, use_rules
        from repro.optim.optimizers import make_optimizer
        from repro.training.train_step import make_train_step
        cfg = R.smoke("qwen2.5-3b")
        shape = ShapeConfig("mini", "train", 64, 8)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = rules_for(cfg, shape, mesh)
        opt = make_optimizer(cfg)
        step = make_train_step(cfg, opt, accum=2)
        p_sds = abstract_params(cfg)
        o_sds = jax.eval_shape(opt.init, p_sds)
        batch, _ = input_specs(cfg, shape)
        with mesh, use_rules(rules, mesh):
            jitted = jax.jit(
                step,
                in_shardings=(policy.param_shardings(cfg, mesh, rules),
                              policy.opt_state_shardings(cfg, opt, mesh, rules),
                              policy.batch_shardings(batch, mesh, rules)),
                donate_argnums=(0, 1))
            compiled = jitted.lower(p_sds, o_sds, batch).compile()
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        print(json.dumps({
            "temp": ma.temp_size_in_bytes,
            "has_allreduce": "all-reduce" in txt,
        }))
    """)
    assert out["temp"] > 0
    assert out["has_allreduce"]


@pytest.mark.xfail(
    strict=False,
    reason="pre-seed failure (one of the 4 known multidev failures tracked in\n"
           "ROADMAP, verified failing at seed commit 29cef53): the pinned jax\n"
           "lacks jax.sharding.AxisType")
def test_compressed_pod_mean_and_elastic():
    out = run_with_devices("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import compressed_cross_pod_mean
        from repro.distributed.elastic import surviving_mesh, reshard, shrink_batch
        from repro.distributed.sharding import ShardingRules
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))
        mean, err = compressed_cross_pod_mean(
            {"w": g}, {"w": jnp.zeros_like(g)}, mesh)
        exact = jnp.mean(g, axis=0)
        rel = float(jnp.abs(mean["w"] - exact).max() / jnp.abs(exact).max())
        mesh2 = jax.make_mesh((4, 2), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        nm = surviving_mesh(mesh2, {mesh2.devices[2, 1].id})
        print(json.dumps({"rel": rel, "rows": nm.devices.shape[0],
                          "batch": shrink_batch(48, 4, nm.devices.shape[0])}))
    """)
    assert out["rel"] < 0.02
    assert out["rows"] == 3 and out["batch"] == 36
