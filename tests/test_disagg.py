"""Disaggregated prefill/decode fleet: role policy and validation, KV
block export pinning, live migration end-to-end (bit-identical greedy,
zero decode-side prompt recompute, leak-free pools), first-token-at-
handoff semantics, deterministic and seeded kv.migrate chaos, and
scheduler load snapshots under in-flight prefill sentinel slots."""
import time

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.kv_pool import KVBlockPool
from repro.serving.router import ReplicaRouter
from repro.serving.sampler import greedy
from repro.serving.scheduler import RequestState


def _smoke():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, sizes, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def _mk_reqs(prompts, new_tokens, rid0=0):
    return [Request(rid0 + i, p, max_new_tokens=new_tokens,
                    sampler=greedy())
            for i, p in enumerate(prompts)]


# -- role policy and validation ------------------------------------------------

def test_role_validation():
    cfg, params = _smoke()
    with pytest.raises(ValueError, match="role="):
        ServingEngine(cfg, params, max_len=24, batch_slots=1,
                      role="prefil")
    # disaggregated roles require the paged engine: migration moves pool
    # blocks, which the contiguous cache does not have
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, max_len=24, batch_slots=1,
                      paged=False, role="prefill")
    # a fleet of only prefill-role replicas has nowhere to send blocks
    pre = ServingEngine(cfg, params, max_len=24, batch_slots=1,
                        paged=True, block_size=8, role="prefill")
    with pytest.raises(ValueError, match="decode-capable"):
        ReplicaRouter([pre])


def test_roles_are_policy_not_capability():
    """A decode-role engine serves a fresh prompt standalone: roles only
    shape router placement, never what an engine can execute (warmup and
    degraded fleets rely on this)."""
    cfg, params = _smoke()
    prompts = _prompts(cfg, [8])
    ref = _mk_reqs(prompts, 4)
    ServingEngine(cfg, params, max_len=24, batch_slots=1, paged=True,
                  block_size=8).serve(ref)
    for role in ("prefill", "decode"):
        reqs = _mk_reqs(prompts, 4)
        eng = ServingEngine(cfg, params, max_len=24, batch_slots=1,
                            paged=True, block_size=8, role=role)
        eng.serve(reqs)
        assert [r.output for r in reqs] == [r.output for r in ref], role
        eng.pool.assert_leak_free()


# -- export pinning ------------------------------------------------------------

def test_export_blocks_pins_and_validates():
    pool = KVBlockPool(8, 8)
    pool.reserve(2)
    ids = pool.alloc_reserved(2)
    gens = pool.export_blocks(ids)
    assert len(gens) == len(ids)
    # one export holder per block on top of the allocation holder
    assert all(pool.refcount(b) == 2 for b in ids)
    assert all(pool.block_live(b, g) for b, g in zip(ids, gens))
    with pytest.raises(ValueError, match="trash"):
        pool.export_blocks([pool.TRASH])
    free_id = next(i for i in range(1, 8) if i not in ids)
    with pytest.raises(ValueError, match="unallocated"):
        pool.export_blocks([free_id])
    # the failed exports must not have leaked partial pins
    assert all(pool.refcount(b) == 2 for b in ids)
    pool.free(ids)              # drop the export pins...
    pool.free(ids)              # ...then the allocation holders
    pool.assert_leak_free()


# -- migration end to end ------------------------------------------------------

def _fleet(cfg, params, plan=None, **kw):
    pre = ServingEngine(cfg, params, name="pre0", role="prefill",
                        fault_plan=plan, **kw)
    dec = ServingEngine(cfg, params, name="dec0", role="decode",
                        fault_plan=plan, **kw)
    return pre, dec


def test_disagg_bit_identical_zero_recompute_leak_free():
    cfg, params = _smoke()
    kw = dict(max_len=64, batch_slots=3, paged=True, block_size=16,
              prefill_chunk=16)
    prompts = _prompts(cfg, [8, 8, 40])
    ref = _mk_reqs(prompts, 4)
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    pre, dec = _fleet(cfg, params, **kw)
    router = ReplicaRouter([pre, dec], affinity=False, steal=False)
    base = dec.begin_window()
    reqs = _mk_reqs(prompts, 4)
    stats = router.serve(reqs)
    router.stop()
    assert [r.output for r in reqs] == [r.output for r in ref], \
        "migrated decode diverged from local prefill+decode"
    assert all(r.first_token_at is not None for r in reqs)
    w = dec.collect_window(base, [], stats.wall_s)
    assert w.prefill_tokens_computed == 0, \
        f"decode replica recomputed {w.prefill_tokens_computed} tokens"
    assert w.kv_migrations == len(reqs)
    assert w.migrated_blocks == sum(
        -(-(len(p) + 4) // 16) for p in prompts)
    pre.pool.assert_leak_free()
    dec.pool.assert_leak_free()


def test_single_token_request_finishes_at_handoff():
    """The first token is sampled on the prefill replica at handoff, so
    a max_new_tokens=1 request is DONE there — no migration, no decode
    replica involvement, still bit-identical to a local serve."""
    cfg, params = _smoke()
    kw = dict(max_len=48, batch_slots=2, paged=True, block_size=16,
              prefill_chunk=16)
    prompts = _prompts(cfg, [8, 24])
    ref = _mk_reqs(prompts, 1)
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    pre, dec = _fleet(cfg, params, **kw)
    router = ReplicaRouter([pre, dec], affinity=False, steal=False)
    base = dec.begin_window()
    reqs = _mk_reqs(prompts, 1)
    stats = router.serve(reqs)
    router.stop()
    assert [r.output for r in reqs] == [r.output for r in ref]
    assert all(r.state is RequestState.DONE for r in reqs)
    w = dec.collect_window(base, [], stats.wall_s)
    assert w.kv_migrations == 0 and w.tokens == 0, \
        "a single-token request must never cross the migration channel"
    pre.pool.assert_leak_free()
    dec.pool.assert_leak_free()


def test_steal_never_raids_the_disagg_migration_path():
    """Work stealing stays on (the relief valve for mixed fleets) but
    must not move fresh prompts onto a decode-role replica, nor pull an
    adopted request — whose KV blocks already landed in the adopter's
    pool — back off its queue to re-prefill it: every prompt migrates
    exactly once and nothing is stolen in a 1+1 disaggregated fleet."""
    cfg, params = _smoke()
    kw = dict(max_len=48, batch_slots=2, paged=True, block_size=16,
              prefill_chunk=16)
    prompts = _prompts(cfg, [8, 8, 24], seed=13)
    ref = _mk_reqs(prompts, 4)
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    pre, dec = _fleet(cfg, params, **kw)
    router = ReplicaRouter([pre, dec], affinity=False, steal=True)
    base = dec.begin_window()
    reqs = _mk_reqs(prompts, 4)
    stats = router.serve(reqs)
    router.stop()
    assert [r.output for r in reqs] == [r.output for r in ref]
    w = dec.collect_window(base, [], stats.wall_s)
    assert w.kv_migrations == len(reqs)
    assert w.prefill_tokens_computed == 0
    assert stats.router_steals == 0
    pre.pool.assert_leak_free()
    dec.pool.assert_leak_free()


def test_migrate_drop_retries_from_bare_prompt():
    cfg, params = _smoke()
    kw = dict(max_len=64, batch_slots=2, paged=True, block_size=16,
              prefill_chunk=16)
    prompts = _prompts(cfg, [8, 40], seed=9)
    ref = _mk_reqs(prompts, 4)
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    plan = FaultPlan([FaultSpec("kv.migrate", "drop", count=1)])
    pre, dec = _fleet(cfg, params, plan=plan, **kw)
    router = ReplicaRouter([pre, dec], affinity=False, steal=False,
                           max_retries=3)
    reqs = _mk_reqs(prompts, 4)
    stats = router.serve(reqs)
    router.stop()
    assert plan.fired == 1
    assert all(r.state is RequestState.DONE for r in reqs), \
        [(r.rid, r.state, r.error) for r in reqs]
    assert [r.output for r in reqs] == [r.output for r in ref], \
        "post-retry outputs diverged from the unfaulted reference"
    assert stats.requests_retried >= 1
    pre.pool.assert_leak_free()
    dec.pool.assert_leak_free()


def test_seeded_migrate_chaos_terminal_and_leak_free():
    """Seeded fault plans over kv.migrate (drop/delay mixes): every
    request must reach a *typed* terminal state — never a hang — DONE
    outputs must match the unfaulted reference, and neither pool may
    leak a block or an export pin."""
    cfg, params = _smoke()
    kw = dict(max_len=48, batch_slots=2, paged=True, block_size=16,
              prefill_chunk=16)
    prompts = _prompts(cfg, [8, 24], seed=11)
    ref = _mk_reqs(prompts, 3)
    ServingEngine(cfg, params, name="ref", **kw).serve(ref)
    ref_out = {r.rid: r.output for r in ref}
    for seed in range(3):
        plan = FaultPlan.from_seed(seed, n=4, sites=("kv.migrate",))
        pre, dec = _fleet(cfg, params, plan=plan, **kw)
        router = ReplicaRouter([pre, dec], affinity=False, steal=False,
                               max_retries=3)
        reqs = _mk_reqs(prompts, 3)
        router.serve(reqs)
        router.stop()
        assert all(r.state in (RequestState.DONE, RequestState.FAILED)
                   for r in reqs), \
            [(r.rid, r.state) for r in reqs]
        for r in reqs:
            if r.state is RequestState.DONE:
                assert r.output == ref_out[r.rid], (seed, r.rid)
            else:
                assert r.error is not None, (seed, r.rid)
        pre.pool.assert_leak_free()
        dec.pool.assert_leak_free()


# -- load snapshots under prefill sentinel slots -------------------------------

def test_load_snapshot_pins_mid_prefill_slot():
    """pos == -1 (admitted, blocks not yet materialized): the snapshot
    must count the slot as occupied and its reservation as spoken-for,
    with exactly the overflow request queued."""
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=32, batch_slots=2,
                        paged=True, block_size=8, pool_blocks=12,
                        prefill_chunk=8)
    free0 = eng.pool.free_blocks
    prompts = _prompts(cfg, [16, 16, 16], seed=5)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4, sampler=greedy()))
    eng._step()      # admits two slots, spends the whole chunk budget
    #                  on the oldest — the second stays at pos == -1
    poses = sorted(j.pos for j in eng._prefilling.values())
    assert poses == [-1, 8], poses
    snap = eng.scheduler.load_snapshot()
    assert snap.free_slots == 0
    assert snap.queued == 1
    assert snap.queued_tokens == 16          # the overflow prompt
    # both admitted requests hold their full 3-block reservation
    # (ceil((16 prompt + 4 new) / 8)) whether materialized or not
    assert free0 - snap.free_blocks == 6
    while eng.scheduler.has_work():
        eng._step()
    eng.pool.assert_leak_free()


def test_load_snapshot_pins_inbound_tier_slot():
    """pos == -2 (materialized, host-tier fetches inbound): the slot is
    skipped by the chunk budget loop but must still read as occupied
    with its blocks allocated; the fetch then lands and decode completes
    bit-identically to an untiered serve."""
    cfg, params = _smoke()
    plan = FaultPlan([FaultSpec("kv.fetch", "delay", delay_s=0.25,
                                count=8)])
    eng = ServingEngine(cfg, params, max_len=24, batch_slots=1,
                        paged=True, block_size=8, pool_blocks=5,
                        host_blocks=16, prefill_chunk=8,
                        fault_plan=plan)
    # three distinct 2-block prefixes through a 4-usable-block pool:
    # the oldest published prefix is demand-demoted to the host tier
    rng = np.random.default_rng(6)
    prefixes = [rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
                for _ in range(3)]
    tails = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
             for _ in range(2)]
    eng.serve([Request(i, np.concatenate([p, tails[0]]),
                       max_new_tokens=3, sampler=greedy())
               for i, p in enumerate(prefixes)])
    assert eng.totals.kv_spills > 0
    prompt = np.concatenate([prefixes[0], tails[1]])
    ref = Request(7, prompt, max_new_tokens=4, sampler=greedy())
    ServingEngine(cfg, params, max_len=24, batch_slots=1, paged=True,
                  block_size=8).serve([ref])
    req = Request(3, prompt, max_new_tokens=4, sampler=greedy())
    eng.submit(req)
    eng._step()      # admission + materialization issue the (delayed)
    #                  fetches; the slot parks at pos == -2
    (job,) = eng._prefilling.values()
    assert job.pos == -2
    snap = eng.scheduler.load_snapshot()
    assert snap.free_slots == 0
    assert snap.queued == 0 and snap.queued_tokens == 0
    assert snap.free_blocks == 0             # 3-block request + trash-
    #                                          excluded pool of 4
    deadline = time.monotonic() + 30.0
    while req.state is not RequestState.DONE:
        assert time.monotonic() < deadline, "inbound-tier slot hung"
        eng._step()
    assert req.output == ref.output, \
        "host-tier restore diverged from the recompute baseline"
    assert eng.totals.prefix_hits_host > 0
    eng.pool.assert_leak_free()
