"""Losses + optimizers: oracles and invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.optim.compression import (dequantize_int8, ef_quantize,
                                     quantize_int8)
from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    constant, global_norm, warmup_cosine)
from repro.training.losses import classification_cross_entropy, lm_cross_entropy


def test_ce_matches_onehot_oracle():
    B, S, V = 2, 5, 11
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, S, V))
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    loss, m = lm_cross_entropy(logits, labels, z_loss=0.0)
    onehot = jax.nn.one_hot(labels, V)
    ref = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))
    np.testing.assert_allclose(loss, ref, rtol=1e-5)


@given(shift=st.floats(-5, 5))
def test_ce_shift_invariance(shift):
    """CE (without z-loss) is invariant to adding a constant to all logits."""
    logits = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 7))
    labels = jnp.array([[1, 2, 3, 4]])
    l1, _ = lm_cross_entropy(logits, labels, z_loss=0.0)
    l2, _ = lm_cross_entropy(logits + shift, labels, z_loss=0.0)
    np.testing.assert_allclose(l1, l2, atol=1e-4)


def test_zloss_penalizes_large_normalizer():
    logits = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 7))
    labels = jnp.zeros((1, 4), jnp.int32)
    l0, _ = lm_cross_entropy(logits, labels, z_loss=0.0)
    l1, _ = lm_cross_entropy(logits + 10.0, labels, z_loss=1e-2)
    assert float(l1) > float(l0)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(36 + 144)) < 1e-4
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_adamw_matches_manual_step():
    opt = adamw(constant(0.1), b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                max_grad_norm=1e9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st_ = opt.init(p)
    new_p, st2, _ = opt.update(g, st_, p)
    mhat = 0.1 * 0.5 / (1 - 0.9)
    vhat = 0.01 * 0.25 / (1 - 0.99)
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_p["w"][0], expect, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_adafactor_factored_state_shapes():
    opt = adafactor(constant(0.01))
    p = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,)),
         "s": jnp.zeros((4, 8, 16))}
    st_ = opt.init(p)
    assert st_["v"]["w"]["vr"].shape == (8,)
    assert st_["v"]["w"]["vc"].shape == (16,)
    assert st_["v"]["b"]["v"].shape == (16,)
    assert st_["v"]["s"]["vr"].shape == (4, 8)
    assert st_["v"]["s"]["vc"].shape == (4, 16)
    g = jax.tree_util.tree_map(jnp.ones_like, p)
    new_p, _, _ = opt.update(g, st_, p)
    assert all(np.isfinite(l).all() for l in jax.tree_util.tree_leaves(new_p))


def test_optimizer_state_axes_match_structure():
    from repro.configs import registry as R
    from repro.distributed.policy import param_axes
    from repro.optim.optimizers import make_optimizer
    cfg = R.smoke("qwen3-moe-235b-a22b")
    axes = param_axes(cfg)
    opt = make_optimizer(cfg)
    import jax
    from repro.models.registry import fns_for
    p = jax.eval_shape(lambda: fns_for(cfg).init(cfg, jax.random.PRNGKey(0)))
    st_shapes = jax.eval_shape(opt.init, p)
    st_axes = opt.state_axes(axes)
    # identical tree structure (axes leaves are tuples/dicts aligned)
    l1 = jax.tree_util.tree_structure(st_shapes)
    l2 = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda t: 0, st_axes,
                               is_leaf=lambda t: isinstance(t, tuple)))
    assert l1 == l2


@given(scale=st.floats(0.01, 100.0))
def test_quantize_roundtrip_bound(scale):
    x = jax.random.normal(jax.random.PRNGKey(4), (64,)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Sum of EF-quantized values over steps tracks the true sum."""
    x = jax.random.normal(jax.random.PRNGKey(5), (32,))
    err = jnp.zeros(32)
    acc = jnp.zeros(32)
    for _ in range(16):
        q, s, err = ef_quantize(x, err)
        acc = acc + dequantize_int8(q, s)
    drift = float(jnp.abs(acc / 16 - x).max())
    q1, s1 = quantize_int8(x)
    one_shot = float(jnp.abs(dequantize_int8(q1, s1) - x).max())
    assert drift < one_shot  # EF beats plain quantization over time


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup=10, total=100, floor=0.1)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
