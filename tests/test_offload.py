"""Offload engine: NCAPI split-phase semantics, ordering, scheduling,
straggler reissue — the paper's protocol invariants."""
import time

import pytest

from repro.core.offload import (JaxTarget, OffloadEngine, SimTarget, Target,
                                WorkItem)


def test_results_in_queueing_order():
    targets = [SimTarget(f"t{i}", compute_s=0.001 * (i + 1)) for i in range(3)]
    with OffloadEngine(targets) as eng:
        results, stats = eng.run(list(range(20)))
    assert results == list(range(20))       # paper Fig 4: collect in order
    assert stats.items == 20


def test_round_robin_assignment():
    targets = [SimTarget(f"t{i}", compute_s=0.001) for i in range(4)]
    with OffloadEngine(targets, scheduler="round_robin") as eng:
        _, stats = eng.run(list(range(16)))
    assert all(v == 4 for v in stats.per_target.values())


def test_least_loaded_prefers_fast_target():
    targets = [SimTarget("slow", compute_s=0.05),
               SimTarget("fast", compute_s=0.002)]
    with OffloadEngine(targets, scheduler="least_loaded") as eng:
        _, stats = eng.run(list(range(24)))
    assert stats.per_target.get("fast", 0) > stats.per_target.get("slow", 0)


def test_split_phase_overlap():
    """Non-blocking load: submit returns before the work completes."""
    t = SimTarget("t", compute_s=0.2)
    with OffloadEngine([t]) as eng:
        t0 = time.monotonic()
        item = eng.submit("x")
        submit_time = time.monotonic() - t0
        assert submit_time < 0.05           # mvncLoadTensor semantics
        assert eng.get_result(item) == "x"


def test_straggler_reissue():
    targets = [SimTarget("stuck", compute_s=5.0),
               SimTarget("ok", compute_s=0.005)]
    with OffloadEngine(targets, deadline_s=0.05) as eng:
        results, stats = eng.run(list(range(6)))
    assert results == list(range(6))
    assert stats.reissues >= 1


def test_multi_device_scaling():
    def mk(n):
        return [SimTarget(f"v{i}", compute_s=0.004, transfer_s=0.001)
                for i in range(n)]
    with OffloadEngine(mk(1)) as eng:
        _, s1 = eng.run(list(range(30)))
    with OffloadEngine(mk(4)) as eng:
        _, s4 = eng.run(list(range(30)))
    assert s4.throughput / s1.throughput > 2.5


def test_jax_target_executes():
    import jax.numpy as jnp
    t = JaxTarget(lambda x: {"y": jnp.asarray(x) * 2}, name="j")
    with OffloadEngine([t]) as eng:
        results, _ = eng.run([1.0, 2.0])
    assert [float(r["y"]) for r in results] == [2.0, 4.0]
