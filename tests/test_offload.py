"""Offload engine: NCAPI split-phase semantics, ordering, scheduling,
straggler reissue — the paper's protocol invariants."""
import time

import pytest

from repro.core.offload import (JaxTarget, OffloadEngine, SimTarget, Target,
                                WorkItem)


def test_results_in_queueing_order():
    targets = [SimTarget(f"t{i}", compute_s=0.001 * (i + 1)) for i in range(3)]
    with OffloadEngine(targets) as eng:
        results, stats = eng.run(list(range(20)))
    assert results == list(range(20))       # paper Fig 4: collect in order
    assert stats.items == 20


def test_exit_closes_every_target_before_raising():
    """A close() that raises (e.g. a wedged replica executor) must not
    skip closing the remaining targets; the first error surfaces after
    all targets had their shutdown."""
    closed = []

    class Flaky(SimTarget):
        def __init__(self, name, fail):
            super().__init__(name, compute_s=0.001)
            self.fail = fail

        def close(self):
            closed.append(self.name)
            super().close()
            if self.fail:
                raise RuntimeError(f"{self.name} wedged")

    targets = [Flaky("t0", fail=True), Flaky("t1", fail=False)]
    with pytest.raises(RuntimeError, match="t0 wedged"):
        with OffloadEngine(targets) as eng:
            eng.run([1, 2])
    assert closed == ["t0", "t1"]           # t1 closed despite t0's raise


def test_round_robin_assignment():
    targets = [SimTarget(f"t{i}", compute_s=0.001) for i in range(4)]
    with OffloadEngine(targets, scheduler="round_robin") as eng:
        _, stats = eng.run(list(range(16)))
    assert all(v == 4 for v in stats.per_target.values())


def test_least_loaded_prefers_fast_target():
    targets = [SimTarget("slow", compute_s=0.05),
               SimTarget("fast", compute_s=0.002)]
    with OffloadEngine(targets, scheduler="least_loaded") as eng:
        _, stats = eng.run(list(range(24)))
    assert stats.per_target.get("fast", 0) > stats.per_target.get("slow", 0)


def test_callable_placement_hook():
    """scheduler may be a placement hook callable(targets, payload) ->
    Target — how the serving replica router scores placement itself while
    riding the engine's submit/drain/reissue machinery unchanged."""
    targets = [SimTarget("even", compute_s=0.002),
               SimTarget("odd", compute_s=0.002)]
    with OffloadEngine(targets,
                       scheduler=lambda ts, payload: ts[payload % 2]) as eng:
        results, stats = eng.run_unordered(list(range(10)))
    assert sorted(seq for seq, _ in results) == list(range(10))
    assert stats.per_target == {"even": 5, "odd": 5}


def test_split_phase_overlap():
    """Non-blocking load: submit returns before the work completes."""
    t = SimTarget("t", compute_s=0.2)
    with OffloadEngine([t]) as eng:
        t0 = time.monotonic()
        item = eng.submit("x")
        submit_time = time.monotonic() - t0
        assert submit_time < 0.05           # mvncLoadTensor semantics
        assert eng.get_result(item) == "x"


def test_straggler_reissue():
    targets = [SimTarget("stuck", compute_s=5.0),
               SimTarget("ok", compute_s=0.005)]
    with OffloadEngine(targets, deadline_s=0.05) as eng:
        results, stats = eng.run(list(range(6)))
    assert results == list(range(6))
    assert stats.reissues >= 1


def test_straggler_reissue_fast_target_wins():
    """An item stuck on an inflated-latency target must be reissued and the
    fast target's result must win (first-completion-wins commit)."""
    targets = [SimTarget("stuck", compute_s=0.5,
                         result_fn=lambda p: ("stuck", p)),
               SimTarget("fast", compute_s=0.005,
                         result_fn=lambda p: ("fast", p))]
    with OffloadEngine(targets, deadline_s=0.05) as eng:
        results, stats = eng.run(list(range(6)))
    assert results == [("fast", p) for p in range(6)]
    assert stats.per_target.get("fast", 0) == 6
    assert stats.reissues >= 3      # every round-robin item on "stuck"


def test_least_loaded_late_binding_prefers_drained_target():
    """With a small dispatch window, least_loaded keys on live queue_depth:
    the fast target drains and receives most of the stream."""
    slow = SimTarget("slow", compute_s=0.08)
    fast = SimTarget("fast", compute_s=0.002)
    with OffloadEngine([slow, fast], scheduler="least_loaded") as eng:
        results, stats = eng.run_unordered(list(range(12)), window=2)
    assert sorted(seq for seq, _ in results) == list(range(12))
    assert stats.per_target.get("fast", 0) > stats.per_target.get("slow", 0)


def test_out_of_order_drain_no_head_of_line():
    """submit_async/drain collects finished items even when an earlier
    item is still running (the fix over ordered `inflight.pop(0)`)."""
    targets = [SimTarget("slow", compute_s=0.3),
               SimTarget("fast", compute_s=0.005)]
    with OffloadEngine(targets) as eng:       # round robin: even seqs slow
        for p in range(4):
            eng.submit_async(p)
        seqs = [item.seq for item in eng.drain(4)]
    assert sorted(seqs) == [0, 1, 2, 3]
    assert seqs[0] in (1, 3)      # a fast item drains before slow seq 0


def test_run_unordered_results_and_window():
    targets = [SimTarget(f"t{i}", compute_s=0.003) for i in range(3)]
    with OffloadEngine(targets) as eng:
        results, stats = eng.run_unordered(list(range(20)), window=4)
    assert sorted(seq for seq, _ in results) == list(range(20))
    assert all(seq == res for seq, res in results)
    assert stats.items == 20


def test_async_on_done_callback_fires_once():
    import threading
    fired = []
    ev = threading.Event()
    t = SimTarget("t", compute_s=0.01)
    with OffloadEngine([t]) as eng:
        eng.submit("x", on_done=lambda it: (fired.append(it.result),
                                            ev.set()))
        assert ev.wait(5)
    assert fired == ["x"]


def test_multi_device_scaling():
    def mk(n):
        return [SimTarget(f"v{i}", compute_s=0.004, transfer_s=0.001)
                for i in range(n)]
    with OffloadEngine(mk(1)) as eng:
        _, s1 = eng.run(list(range(30)))
    with OffloadEngine(mk(4)) as eng:
        _, s4 = eng.run(list(range(30)))
    assert s4.throughput / s1.throughput > 2.5


def test_jax_target_executes():
    import jax.numpy as jnp
    t = JaxTarget(lambda x: {"y": jnp.asarray(x) * 2}, name="j")
    with OffloadEngine([t]) as eng:
        results, _ = eng.run([1.0, 2.0])
    assert [float(r["y"]) for r in results] == [2.0, 4.0]
