"""Data pipeline determinism + config registry integrity."""
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.specs import input_specs
from repro.data.pipeline import Prefetcher, SyntheticImages, SyntheticTokens


def test_all_archs_present_with_exact_dims():
    expect = {
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }
    assert set(R.ARCH_IDS) == set(expect)
    for arch, (L, d, H, K, ff, V) in expect.items():
        c = R.config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, K, ff, V), arch


def test_long500k_skips_match_design():
    runs_long = {a for a in R.ARCH_IDS
                 if "long_500k" not in R.get(a).skipped}
    assert runs_long == {"zamba2-1.2b", "xlstm-125m"}


def test_input_specs_cover_all_cells():
    for arch in R.ARCH_IDS:
        a = R.get(arch)
        for sname in a.shapes:
            if sname in a.skipped:
                continue
            shape = SHAPES_BY_NAME[sname]
            batch, state = input_specs(a.model, shape)
            assert batch["tokens"].shape[0] == shape.global_batch
            if shape.kind == "decode":
                assert state is not None
                assert batch["tokens"].shape == (shape.global_batch, 1)


def test_synthetic_tokens_deterministic():
    cfg = R.smoke("qwen2.5-3b")
    a = next(iter(SyntheticTokens(cfg, 2, 8, seed=3)))
    b = next(iter(SyntheticTokens(cfg, 2, 8, seed=3)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < cfg.vocab_size


def test_images_deterministic_and_class_dependent():
    s1 = SyntheticImages(batch=4, size=16, seed=1).sample(64)
    s2 = SyntheticImages(batch=4, size=16, seed=1).sample(64)
    np.testing.assert_array_equal(s1["images"], s2["images"])
    # class signal present: per-class means differ
    m0 = s1["images"][s1["labels"] < 500].mean()
    m1 = s1["images"][s1["labels"] >= 500].mean()
    assert abs(m0 - m1) > 0.01


def test_prefetcher_preserves_order():
    cfg = R.smoke("qwen2.5-3b")

    def gen():
        for i in range(5):
            yield {"i": np.array([i])}

    out = [b["i"][0] for b in Prefetcher(gen())]
    assert out == [0, 1, 2, 3, 4]
