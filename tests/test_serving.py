"""Serving engine: outputs match direct greedy decode; stats sane."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.models.registry import fns_for
from repro.serving.engine import MultiReplicaEngine, Request, ServingEngine
from repro.serving.sampler import greedy, temperature


def _direct_greedy(cfg, params, prompt, n_new, max_len):
    fns = fns_for(cfg)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((1, cfg.encdec.num_encoder_frames,
                                     cfg.d_model), jnp.float32)
    lg, st = fns.prefill(cfg, params, batch, max_len=max_len)
    out = []
    for _ in range(n_new):
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        lg, st = fns.decode(cfg, params, jnp.asarray([[tok]], jnp.int32), st)
    return out


def test_engine_matches_direct_decode():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
               for _ in range(3)]
    eng = ServingEngine(cfg, params, max_len=16, batch_slots=2)
    reqs = [Request(i, p, max_new_tokens=4, sampler=greedy())
            for i, p in enumerate(prompts)]
    eng.serve(reqs)
    for r, p in zip(reqs, prompts):
        assert r.output == _direct_greedy(cfg, params, p, 4, 16), r.rid


def test_sampler_temperature_topk():
    logits = np.array([10.0, 9.0, -50.0, -50.0])
    s = temperature(0.5, top_k=2, seed=0)
    picks = {s(logits) for _ in range(20)}
    assert picks <= {0, 1}
    assert greedy()(logits) == 0


def test_multireplica_counts():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    replicas = [ServingEngine(cfg, params, max_len=12, batch_slots=2)
                for _ in range(2)]
    reqs = [Request(i, np.arange(6, dtype=np.int32), max_new_tokens=3)
            for i in range(6)]
    stats = MultiReplicaEngine(replicas).serve(reqs, group_size=2)
    assert stats.tokens == 18
    assert stats.requests == 6
