"""Serving engine: continuous batching matches direct greedy decode; late
short requests overtake long ones; multi-replica pull; vectorized sampling."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import MultiReplicaEngine
from repro.serving.sampler import greedy, temperature


def _smoke():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _direct_greedy(cfg, params, prompt, n_new, max_len):
    fns = fns_for(cfg)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((1, cfg.encdec.num_encoder_frames,
                                     cfg.d_model), jnp.float32)
    lg, st = fns.prefill(cfg, params, batch, max_len=max_len)
    out = []
    for _ in range(n_new):
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        lg, st = fns.decode(cfg, params, jnp.asarray([[tok]], jnp.int32), st)
    return out


def test_engine_matches_direct_decode():
    cfg, params = _smoke()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
               for _ in range(3)]
    eng = ServingEngine(cfg, params, max_len=16, batch_slots=2)
    reqs = [Request(i, p, max_new_tokens=4, sampler=greedy())
            for i, p in enumerate(prompts)]
    eng.serve(reqs)
    for r, p in zip(reqs, prompts):
        assert r.output == _direct_greedy(cfg, params, p, 4, 16), r.rid


def test_wave_path_matches_continuous():
    """Legacy lock-step decode (benchmark baseline) produces identical
    greedy outputs to continuous batching."""
    cfg, params = _smoke()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(4)]
    mk = lambda: [Request(i, p, max_new_tokens=3, sampler=greedy())  # noqa
                  for i, p in enumerate(prompts)]
    eng = ServingEngine(cfg, params, max_len=12, batch_slots=2)
    cont, wave = mk(), mk()
    eng.serve(cont)
    eng.serve_wave(wave)
    assert [r.output for r in cont] == [r.output for r in wave]


def test_mixed_lengths_and_slot_refill():
    """Short requests free their slots for queued ones; stats track
    occupancy and per-request latency."""
    cfg, params = _smoke()
    rng = np.random.default_rng(2)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=6)
                    .astype(np.int32),
                    max_new_tokens=2 if i % 2 else 6, sampler=greedy())
            for i in range(6)]
    eng = ServingEngine(cfg, params, max_len=16, batch_slots=2)
    stats = eng.serve(reqs)
    assert [len(r.output) for r in reqs] == [6, 2, 6, 2, 6, 2]
    assert stats.tokens == 24
    assert stats.prefills == 6
    assert 0.0 < stats.slot_occupancy <= 1.0
    assert len(stats.ttft) == 6 and stats.ttft_p50_s is not None
    # continuous batching needs fewer decode steps than lock-step waves
    # (3 waves x 6 steps) would
    assert stats.decode_steps < 18


def test_late_short_request_finishes_first():
    """A short request admitted mid-stream completes without waiting for an
    earlier long request's full decode (the continuous-batching invariant
    the wave path cannot satisfy)."""
    cfg, params = _smoke()
    prompt = np.arange(8, dtype=np.int32)
    long_req = Request(0, prompt, max_new_tokens=30, sampler=greedy())
    short_req = Request(1, prompt, max_new_tokens=3, sampler=greedy())
    ev_long, ev_short = threading.Event(), threading.Event()
    eng = ServingEngine(cfg, params, max_len=48, batch_slots=2)
    eng.start()
    try:
        eng.submit(long_req, on_finish=lambda r: ev_long.set())
        deadline = time.monotonic() + 60
        while long_req.first_token_at is None:   # long is mid-decode
            assert time.monotonic() < deadline, "long request never started"
            time.sleep(0.005)
        eng.submit(short_req, on_finish=lambda r: ev_short.set())
        assert ev_short.wait(60) and ev_long.wait(60)
    finally:
        eng.stop()
    assert len(short_req.output) == 3 and len(long_req.output) == 30
    assert short_req.finished_at < long_req.finished_at


def test_rejects_request_exceeding_kv_capacity():
    """Out-of-range cache writes clamp silently under jit — the engine must
    reject a request that cannot fit instead of corrupting generation."""
    import pytest
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=10, batch_slots=2)
    too_big = Request(0, np.arange(8, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(ValueError, match="KV capacity"):
        eng.serve([too_big])
    with pytest.raises(ValueError, match="KV capacity"):
        eng.submit(too_big)
    with pytest.raises(ValueError, match="KV capacity"):
        eng.serve_wave([too_big])
    # boundary: prompt + new == max_len + 1 still fits (last token needs
    # no cache write)
    ok = Request(1, np.arange(8, dtype=np.int32), max_new_tokens=3)
    stats = eng.serve([ok])
    assert stats.tokens == 3


def test_sampler_temperature_topk():
    logits = np.array([10.0, 9.0, -50.0, -50.0])
    s = temperature(0.5, top_k=2, seed=0)
    picks = {s(logits) for _ in range(20)}
    assert picks <= {0, 1}
    assert greedy()(logits) == 0


def test_sampler_vectorized_batch():
    logits = np.array([[5.0, 1.0, 0.0], [0.0, 1.0, 5.0], [1.0, 9.0, 0.0]])
    assert greedy().sample(logits).tolist() == [0, 2, 1]
    out = temperature(0.3, top_k=1, seed=0).sample(logits)
    assert out.tolist() == [0, 2, 1]            # top-1 == greedy
    # stateless greedy slots share one batch group; temperature is per-rng
    assert greedy().batch_key == greedy().batch_key
    assert temperature(0.5).batch_key != temperature(0.5).batch_key


def test_multireplica_counts():
    cfg, params = _smoke()
    replicas = [ServingEngine(cfg, params, max_len=12, batch_slots=2)
                for _ in range(2)]
    reqs = [Request(i, np.arange(6, dtype=np.int32), max_new_tokens=3)
            for i in range(6)]
    stats = MultiReplicaEngine(replicas).serve(reqs)
    assert stats.tokens == 18
    assert stats.requests == 6
    assert all(len(r.output) == 3 for r in reqs)
    assert stats.prefills == 6


def test_multireplica_aggregates_paged_pool_stats():
    """Regression: multi-replica serving never populated the paged-KV pool
    metrics even when every replica was paged — peaks are now summed and
    utilization is peak over combined capacity."""
    cfg, params = _smoke()
    replicas = [ServingEngine(cfg, params, max_len=16, batch_slots=2,
                              paged=True) for _ in range(2)]
    reqs = [Request(i, np.arange(6, dtype=np.int32), max_new_tokens=3)
            for i in range(6)]
    stats = MultiReplicaEngine(replicas).serve(reqs)
    assert stats.kv_blocks_peak is not None and stats.kv_blocks_peak >= 1
    assert stats.kv_blocks_peak <= sum(e.pool.capacity for e in replicas)
    assert 0.0 < stats.kv_pool_util <= 1.0
    # arrival is stamped at hand-off, so TTFT survives the clone round-trip
    assert len(stats.ttft) == 6


def test_stop_raises_when_executor_wedged():
    """Regression: stop() used to drop the thread handle even when join
    timed out, letting a later start() race two executors over _state."""
    import pytest
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=12, batch_slots=1)
    gate = threading.Event()
    wedged = threading.Thread(target=gate.wait, daemon=True)
    wedged.start()
    eng._thread = wedged                # simulate a stuck executor thread
    with pytest.raises(RuntimeError, match="did not stop"):
        eng.stop(timeout=0.05)
    assert eng._thread is wedged        # handle retained, no silent leak
    gate.set()
    wedged.join(timeout=5)
    eng._thread = None


def test_preempted_decode_resumes_and_completes_correctly():
    """Preemption lifecycle end to end: a high-priority arrival evicts the
    only active decode; the victim re-queues with its generated tokens
    folded into its prompt, re-prefills on re-admission, and still
    produces exactly the un-preempted greedy output."""
    cfg, params = _smoke()
    prompt = (np.arange(8, dtype=np.int32) * 7) % cfg.vocab_size
    expect = _direct_greedy(cfg, params, prompt, 24, 36)
    eng = ServingEngine(cfg, params, max_len=33, batch_slots=1, paged=True,
                        block_size=4, pool_blocks=8)
    low = Request(0, prompt, max_new_tokens=24, sampler=greedy())
    high = Request(1, np.arange(4, dtype=np.int32), max_new_tokens=2,
                   sampler=greedy(), priority=1)
    ev_low, ev_high = threading.Event(), threading.Event()
    eng.start()
    try:
        eng.submit(low, on_finish=lambda r: ev_low.set())
        deadline = time.monotonic() + 60
        while low.first_token_at is None:       # low is mid-decode
            assert time.monotonic() < deadline, "low request never started"
            time.sleep(0.005)
        eng.submit(high, on_finish=lambda r: ev_high.set())
        assert ev_high.wait(60) and ev_low.wait(60)
    finally:
        eng.stop()
    assert low.preempted_count >= 1             # eviction really happened
    assert eng.scheduler.preemptions >= 1
    assert len(high.output) == 2
    assert low.output == expect                 # recompute-resume is exact
    # reservation accounting balanced after the whole dance
    assert eng.pool.used_blocks == 0 and eng.pool.reserved_blocks == 0
