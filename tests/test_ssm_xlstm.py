"""SSM / xLSTM recurrence cores: chunked-parallel vs sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.configs import registry as R
from repro.models.layers.ssm import (chunked_linear_attn, linear_attn_step,
                                     mamba_forward, mamba_init_state,
                                     mamba_step, mamba_table)
from repro.models.layers.module import init_table
from repro.models.layers import xlstm as X


def _sequential(q, k, v, ld, lg, h0):
    S = q.shape[1]
    h = h0
    ys = []
    for t in range(S):
        y, h = linear_attn_step(q[:, t], k[:, t], v[:, t], ld[:, t],
                                lg[:, t], h)
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@given(chunk=st.sampled_from([4, 8, 16, 40]))
def test_chunk_size_independence(chunk):
    B, S, H, N, P = 1, 40, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    lg = 0.2 * jax.random.normal(ks[4], (B, S, H))
    y, hf = chunked_linear_attn(q, k, v, ld, lg, chunk=chunk,
                                return_final_state=True)
    y_ref, h_ref = _sequential(q, k, v, ld, lg, jnp.zeros((B, H, N, P)))
    np.testing.assert_allclose(y, y_ref, atol=2e-4)
    np.testing.assert_allclose(hf, h_ref, atol=2e-4)


def test_mamba_forward_vs_step():
    cfg = R.smoke("zamba2-1.2b")
    params = init_table(jax.random.PRNGKey(0), mamba_table(cfg), "float32")
    B, S = 2, 12
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out_full, fin = mamba_forward(cfg, params, u, return_state=True)
    st = mamba_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, st = mamba_step(cfg, params, u[:, t:t + 1], st)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out_full, out_seq, atol=3e-4)
    np.testing.assert_allclose(fin.ssm, st.ssm, atol=3e-4)


def test_mlstm_forward_vs_step():
    cfg = R.smoke("xlstm-125m")
    params = init_table(jax.random.PRNGKey(0), X.mlstm_table(cfg), "float32")
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out_full, fin = X.mlstm_forward(cfg, params, x, return_state=True)
    st = X.mlstm_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, st = X.mlstm_step(cfg, params, x[:, t:t + 1], st)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out_full, out_seq, atol=3e-4)
    np.testing.assert_allclose(fin.mem, st.mem, atol=3e-4)


def test_slstm_state_continuation():
    """Running sLSTM over [0:S] == running [0:k] then [k:S] with the state."""
    cfg = R.smoke("xlstm-125m")
    params = init_table(jax.random.PRNGKey(0), X.slstm_table(cfg), "float32")
    B, S, k = 2, 12, 5
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    out_full, fin = X.slstm_forward(cfg, params, x, return_state=True)
    o1, st = X.slstm_forward(cfg, params, x[:, :k], return_state=True)
    o2, st2 = X.slstm_forward(cfg, params, x[:, k:], st, return_state=True)
    np.testing.assert_allclose(out_full, jnp.concatenate([o1, o2], 1),
                               atol=3e-5)
    np.testing.assert_allclose(fin.c, st2.c, atol=3e-5)


def test_decay_monotonicity():
    """With log_gate=-inf after t0, outputs must decay toward 0 (state decays)."""
    B, S, H, N, P = 1, 30, 1, 2, 2
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jnp.ones((B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, P))
    ld = jnp.full((B, S, H), -0.5)
    lg = jnp.where(jnp.arange(S)[None, :, None] < 5, 0.0, -1e30)
    y, _ = chunked_linear_attn(q, k, v, ld, lg, chunk=8)
    norms = jnp.linalg.norm(y[0, :, 0], axis=-1)
    assert float(norms[29]) < float(norms[5]) * 0.01
