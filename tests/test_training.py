"""Trainer loop: learning, checkpoint/auto-resume, fault recovery."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import registry as R
from repro.data.pipeline import SyntheticTokens
from repro.distributed.fault import FaultSchedule, SimulatedFault, with_retries
from repro.optim.optimizers import adamw, warmup_cosine
from repro.training.trainer import Trainer, TrainerConfig


def _trainer(tmp, steps=10, events=None, ckpt_every=4):
    cfg = R.smoke("qwen2.5-3b")
    data = SyntheticTokens(cfg, batch=4, seq_len=16)
    tc = TrainerConfig(num_steps=steps, ckpt_every=ckpt_every, ckpt_dir=tmp,
                       async_save=False)
    return Trainer(cfg, iter(data), tc,
                   optimizer=adamw(warmup_cosine(3e-3, 3, steps)),
                   fault_schedule=FaultSchedule(events=events or {}))


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=25)
        hist = tr.train()
        losses = [h["loss"] for h in hist if "loss" in h]
        assert losses[-1] < losses[0]


def test_crash_recovery_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=12, events={9: "crash"})
        hist = tr.train()
        events = [h for h in hist if "event" in h]
        assert len(events) == 1 and events[0]["event"] == "crash"
        steps_run = [h["step"] for h in hist if "loss" in h]
        assert steps_run.count(8) == 2      # step 8 re-ran after restore
        assert tr.step == 12


def test_auto_resume_continues():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=8)
        tr.train()
        tr2 = _trainer(d, steps=12)
        assert tr2.try_resume()
        assert tr2.step == 8
        tr2.train()
        assert tr2.step == 12


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=False)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": [jnp.zeros(4, jnp.int32), jnp.ones(())]}
        for step in (1, 2, 3, 4):
            ck.save(step, tree)
        assert ck.all_steps() == [3, 4]      # retention
        restored = ck.restore(4, tree)
        np.testing.assert_array_equal(restored["a"], np.asarray(tree["a"]))
        assert ck.latest_step() == 4


def test_checkpoint_atomicity():
    """A stray .tmp dir must never be visible as a checkpoint."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(1, {"x": jnp.ones(3)})
        os.makedirs(os.path.join(d, "step_00000002.tmp0"))
        assert ck.all_steps() == [1]
        assert ck.latest_step() == 1


def test_with_retries_recovers():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise SimulatedFault(0, "crash")
        return "ok"

    assert with_retries(flaky, attempts=3) == "ok"


def test_straggler_fault_is_nonfatal():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, steps=6, events={2: "straggler"})
        hist = tr.train()
        assert len([h for h in hist if "loss" in h]) == 6
