"""Continuous-batching scheduler: lifecycle, slot bookkeeping, SLO-aware
admission (priority / deadline / arrival order), decode preemption."""
import math
import time

import numpy as np
import pytest

from repro.serving.kv_pool import KVBlockPool
from repro.serving.scheduler import (ContinuousScheduler, Request,
                                     RequestState)


def _req(rid, n=4, **kw):
    return Request(rid, np.arange(6, dtype=np.int32), max_new_tokens=n, **kw)


def test_lifecycle_states():
    s = ContinuousScheduler(2)
    r = _req(0)
    s.submit(r)
    assert r.state is RequestState.QUEUED
    assert s.queued == 1 and s.occupied == 0
    [(slot, admitted)] = s.admit()
    assert admitted is r and r.state is RequestState.PREFILL
    assert s.queued == 0 and s.occupied == 1 and s.load == 1
    r.state = RequestState.DONE
    assert s.release(slot) is r
    assert s.occupied == 0 and not s.has_work()


def test_admit_fills_free_slots_fifo():
    s = ContinuousScheduler(2)
    for i in range(5):
        s.submit(_req(i))
    first = s.admit()
    assert [r.rid for _, r in first] == [0, 1]
    assert s.admit() == []                      # slots full
    assert s.queued == 3
    # the moment a slot frees, the next queued request takes exactly it
    slot = first[0][0]
    s.release(slot)
    [(slot2, nxt)] = s.admit()
    assert slot2 == slot and nxt.rid == 2


def test_active_and_load_reflect_slots_and_queue():
    s = ContinuousScheduler(3)
    for i in range(4):
        s.submit(_req(i))
    s.admit()
    assert {r.rid for _, r in s.active()} == {0, 1, 2}
    assert s.load == 4 and s.queued == 1
    assert s.has_work()


def test_wait_for_work_signals_on_submit():
    s = ContinuousScheduler(1)
    assert not s.wait_for_work(timeout=0.01)
    s.submit(_req(0))
    assert s.wait_for_work(timeout=0.01)


def test_request_metrics_and_clone():
    r = _req(7, priority=3, slo_ttft_s=0.4)
    r.submitted_at = 10.0
    r.first_token_at = 10.5
    r.finished_at = 11.5
    r.output = [1, 2, 3]
    assert r.ttft_s == 0.5
    assert abs(r.tpot_s - 0.5) < 1e-9
    assert r.slo_miss is True                   # 0.5s TTFT > 0.4s SLO
    c = r.clone()
    assert c.rid == 7 and c.output == [] and c.first_token_at is None
    assert c.submitted_at == 10.0               # TTFT measured from arrival
    assert c.priority == 3 and c.slo_ttft_s == 0.4
    assert c.arrival_seq is None                # fresh seq per scheduler


def test_submit_stamps_submitted_at_at_submission():
    """Regression: submitted_at used to be stamped at Request construction,
    inflating TTFT for any pre-constructed request."""
    r = _req(0)
    assert r.submitted_at is None               # construction does not stamp
    time.sleep(0.03)
    s = ContinuousScheduler(1)
    t0 = time.monotonic()
    s.submit(r)
    assert r.submitted_at is not None and abs(r.submitted_at - t0) < 0.02
    # a pre-stamped arrival (multi-replica reissue clone) is preserved
    r2 = _req(1)
    r2.submitted_at = 123.0
    s.submit(r2)
    assert r2.submitted_at == 123.0


def test_admission_order_priority_then_deadline_then_arrival():
    s = ContinuousScheduler(1)
    r_bg = _req(0, priority=0)                      # background, first in
    r_slo_loose = _req(1, priority=1, slo_ttft_s=9.0)
    r_slo_tight = _req(2, priority=1, slo_ttft_s=0.1)  # later, tighter SLO
    r_plain = _req(3, priority=1)                   # no SLO: last in tier
    for r in (r_bg, r_slo_loose, r_slo_tight, r_plain):
        s.submit(r)
    order = []
    while s.has_work():
        [(slot, r)] = s.admit()
        r.state = RequestState.DONE
        s.release(slot)
        order.append(r.rid)
    assert order == [2, 1, 3, 0]


def test_property_admission_order():
    """Property: drain order equals sorting by (priority desc, SLO
    deadline, arrival) for any mix of priorities and SLOs."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    @given(st.lists(st.tuples(st.integers(0, 3),
                              st.one_of(st.none(),
                                        st.floats(0.01, 10.0))),
                    min_size=1, max_size=12))
    def prop(specs):
        s = ContinuousScheduler(1)
        reqs = []
        for i, (pri, slo) in enumerate(specs):
            r = _req(i, priority=pri, slo_ttft_s=slo)
            r.submitted_at = float(i)       # deterministic deadlines
            s.submit(r)
            reqs.append(r)
        drained = []
        while s.has_work():
            [(slot, r)] = s.admit()
            r.state = RequestState.DONE
            s.release(slot)
            drained.append(r.rid)

        def key(r):
            dl = (r.submitted_at + r.slo_ttft_s
                  if r.slo_ttft_s is not None else math.inf)
            return (-r.priority, dl, r.arrival_seq)

        assert drained == [r.rid for r in sorted(reqs, key=key)]

    prop()


# -- work stealing -------------------------------------------------------------

def test_steal_takes_back_of_queue_and_preserves_order():
    """Steal removes the lowest-ranked queued requests (the ones this
    scheduler would serve last), never the head, and the surviving heap
    drains in unchanged (priority, deadline, arrival) order."""
    s = ContinuousScheduler(1)
    reqs = [_req(0, priority=2), _req(1, priority=0), _req(2, priority=1),
            _req(3, priority=0)]
    for r in reqs:
        s.submit(r)
    got = s.steal(max_items=2)
    # victims: both priority-0 requests, latest arrival first
    assert [r.rid for r in got] == [3, 1]
    assert all(r.arrival_seq is None for r in got)      # thief re-seqs
    order = []
    while s.has_work():
        [(slot, r)] = s.admit()
        r.state = RequestState.DONE
        s.release(slot)
        order.append(r.rid)
    assert order == [0, 2]                              # head untouched


def test_steal_respects_thief_admission_filter():
    """``can_take`` filters candidates by the thief's admission capacity
    (computed in the THIEF's geometry, not this scheduler's pool): a
    request the thief could not admit must stay queued here instead of
    ping-ponging between replicas — and a filtered scan must not walk
    forward into the head of the queue."""
    pool = KVBlockPool(16, block_size=4)
    s = ContinuousScheduler(1, pool=pool)
    head = _req(0, n=3)                         # 8 rows, first in = head
    big = Request(1, np.arange(8, dtype=np.int32), max_new_tokens=17)
    tail = _req(2, n=3)                         # 8 rows, back of queue
    for r in (head, big, tail):                 # big: 24 rows
        s.submit(r)
    # thief with 1 free 4-token block: nothing fits (8 rows -> 2 blocks)
    assert s.steal(max_items=3,
                   can_take=lambda r: -(-r.kv_rows // 4) <= 1) == []
    # thief with 2 free 4-token blocks: tail fits, big skipped, and the
    # scan never reaches the (equally fitting) head
    got = s.steal(max_items=3,
                  can_take=lambda r: -(-r.kv_rows // 4) <= 2)
    assert [r.rid for r in got] == [2]
    # every remaining non-head candidate fails the filter: still no head
    assert s.steal(max_items=1,
                   can_take=lambda r: -(-r.kv_rows // 4) <= 2) == []
    assert s.queued == 2                        # head + big stayed


def test_steal_protects_head_unless_sole_entry():
    """While other entries are queued the head is never shipped away; a
    sole queued request (the donor has no capacity for it now) may
    migrate to an idle peer."""
    s = ContinuousScheduler(1)
    s.submit(_req(0))
    s.submit(_req(1))
    assert [r.rid for r in s.steal(max_items=5)] == [1]
    assert s.queued == 1                        # the head survived...
    assert [r.rid for r in s.steal(max_items=5)] == [0]
    assert s.queued == 0                        # ...until it stood alone


def test_steal_preserves_submitted_at_for_ttft():
    """A stolen request's TTFT keeps measuring from its *original*
    submission: steal never clears ``submitted_at``, and the thief's
    submit preserves a pre-stamped arrival."""
    donor, thief = ContinuousScheduler(1), ContinuousScheduler(1)
    donor.submit(_req(0))                       # head stays with the donor
    r = _req(1)
    donor.submit(r)
    stamped = r.submitted_at
    assert stamped is not None
    time.sleep(0.02)
    [stolen] = donor.steal()
    assert stolen is r
    thief.submit(stolen)
    assert stolen.submitted_at == stamped       # migration is TTFT-neutral
    stolen.first_token_at = stamped + 1.0
    assert stolen.ttft_s == 1.0


def test_property_steal_partitions_and_orders():
    """Property: stealing k requests from a loaded scheduler into a second
    one (with its own backlog) never duplicates or loses a request, and
    both heaps still drain in (priority desc, SLO deadline, arrival)
    order with ``submitted_at``, priority, and SLO preserved."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    spec = st.tuples(st.integers(0, 3),
                     st.one_of(st.none(), st.floats(0.01, 10.0)))

    @given(st.lists(spec, min_size=1, max_size=10),
           st.lists(spec, min_size=0, max_size=6),
           st.integers(0, 10))
    def prop(donor_specs, thief_specs, k):
        donor, thief = ContinuousScheduler(1), ContinuousScheduler(1)
        all_reqs = {}
        for i, (pri, slo) in enumerate(donor_specs):
            r = _req(i, priority=pri, slo_ttft_s=slo)
            r.submitted_at = float(i)           # deterministic deadlines
            donor.submit(r)
            all_reqs[i] = r
        for i, (pri, slo) in enumerate(thief_specs):
            r = _req(100 + i, priority=pri, slo_ttft_s=slo)
            r.submitted_at = float(100 + i)
            thief.submit(r)
            all_reqs[100 + i] = r
        stamps = {rid: r.submitted_at for rid, r in all_reqs.items()}
        meta = {rid: (r.priority, r.slo_ttft_s)
                for rid, r in all_reqs.items()}

        stolen = donor.steal(max_items=k)
        for r in stolen:
            thief.submit(r)

        def drain(s):
            out = []
            while s.has_work():
                [(slot, r)] = s.admit()
                r.state = RequestState.DONE
                s.release(slot)
                out.append(r)
            return out

        drained = drain(donor) + drain(thief)
        # partition: every request served exactly once, none invented
        assert sorted(r.rid for r in drained) == sorted(all_reqs)
        for r in drained:                       # migration mutates nothing
            assert r.submitted_at == stamps[r.rid]
            assert (r.priority, r.slo_ttft_s) == meta[r.rid]

        def key(r):
            dl = (r.submitted_at + r.slo_ttft_s
                  if r.slo_ttft_s is not None else math.inf)
            return (-r.priority, dl)

        # both heaps drained in sorted order (arrival seq is the only
        # tiebreak hypothesis cannot see; compare the visible key)
        n_donor = len(donor_specs) - len(stolen)
        for part in (drained[:n_donor], drained[n_donor:]):
            keys = [key(r) for r in part]
            assert keys == sorted(keys)

    prop()


# -- preemption ----------------------------------------------------------------

def _admit_and_decode(s, pool, prompt_blocks):
    """Simulate the engine side of admission: materialize prompt blocks
    and flip the request to DECODE (the state preemption targets)."""
    out = []
    for slot, r in s.admit():
        r.block_ids = pool.alloc_reserved(prompt_blocks)
        r.blocks_reserved -= prompt_blocks
        r.state = RequestState.DECODE
        out.append((slot, r))
    return out


def test_preemption_lifecycle_accounting_balanced():
    pool = KVBlockPool(8, block_size=4)
    s = ContinuousScheduler(2, pool=pool)
    lows = [_req(i, n=9) for i in range(2)]     # 16 rows -> 4 blocks each
    for r in lows:
        s.submit(r)
    assert len(_admit_and_decode(s, pool, 2)) == 2
    assert pool.free_blocks == 0                # 4 allocated + 4 promised

    high = Request(9, np.arange(6, dtype=np.int32), max_new_tokens=3,
                   priority=1)                  # 8 rows -> 2 blocks
    s.submit(high)
    admitted = s.admit()
    # high evicted exactly one low (ties broken deterministically) and
    # took its slot; the victim's blocks and reservation tail returned
    assert [r.rid for _, r in admitted] == [9]
    assert s.preemptions == 1
    [(vslot, victim)] = s.drain_preempted()
    assert s.drain_preempted() == []            # drained exactly once
    assert victim in lows and victim.state is RequestState.QUEUED
    assert victim.preempted_count == 1
    assert victim.block_ids == [] and victim.blocks_reserved == 0
    assert admitted[0][0] == vslot              # victim's slot reused
    # pool: surviving low holds 2 + 2 promised; high has 2 promised
    assert pool.used_blocks == 2
    assert pool.reserved_blocks == 4
    assert s.queued == 1                        # victim re-queued

    # high materializes its prompt blocks, runs, and finishes
    high.block_ids = pool.alloc_reserved(2)
    high.blocks_reserved -= 2
    high.state = RequestState.DONE
    s.release(vslot)
    # ...then the victim re-admits into the freed capacity and completes
    readmitted = _admit_and_decode(s, pool, 2)
    assert [r for _, r in readmitted] == [victim]
    for slot, r in s.active():
        r.state = RequestState.DONE
        s.release(slot)
    assert pool.used_blocks == 0 and pool.reserved_blocks == 0
    assert pool.free_blocks == 8                # fully balanced


def test_no_preemption_within_equal_priority_or_when_disabled():
    for preemption in (True, False):
        pool = KVBlockPool(4, block_size=4)
        s = ContinuousScheduler(1, pool=pool, preemption=preemption)
        low = _req(0, n=9, priority=0)          # 16 rows -> 4 blocks
        s.submit(low)
        _admit_and_decode(s, pool, 2)
        # equal priority never evicts; disabled preemption never evicts
        s.submit(_req(1, n=3, priority=0 if preemption else 5))
        assert s.admit() == []
        assert s.preemptions == 0 and low.state is RequestState.DECODE


def test_preemption_gain_ignores_shared_out_blocks():
    """A victim whose prompt blocks are prefix-shared with other holders
    frees only its reservation tail on eviction — the gain estimate must
    not count shared blocks, or a doomed eviction throws work away."""
    pool = KVBlockPool(4, block_size=4)
    s = ContinuousScheduler(1, pool=pool)
    low = _req(0, n=11)                         # 16 rows -> 4 blocks
    s.submit(low)
    _admit_and_decode(s, pool, 2)               # 2 allocated + 2 tail
    pool.share(low.block_ids)                   # another request shares them
    s.submit(_req(9, n=7, priority=1))          # 12 rows -> needs 3 blocks
    # evicting low would free only its 2-block tail (shared blocks stay)
    assert s.admit() == []
    assert s.preemptions == 0 and low.state is RequestState.DECODE
    pool.free(low.block_ids)                    # drop the sharer's hold
    assert s.admit() != []                      # now eviction covers need
    assert s.preemptions == 1


def test_blocked_head_admission_check_cached_until_capacity_event():
    """An unfit queue head is re-priced only after a capacity event (slot
    release, pool headroom growth, submit), not every executor step — the
    cached verdict is provably identical in between."""
    pool = KVBlockPool(4, block_size=4)
    s = ContinuousScheduler(1, pool=pool)
    r0 = _req(0, n=3)                           # 8 rows -> 2 blocks
    s.submit(r0)
    [(slot, _)] = _admit_and_decode(s, pool, 2)
    big = _req(1, n=11)                         # 16 rows -> 4 blocks
    s.submit(big)
    assert s.admit() == []                      # full check, verdict cached
    base = s.head_checks_skipped
    for _ in range(5):
        assert s.admit() == []                  # cached: no slot scan, no
    assert s.head_checks_skipped == base + 5    # reserve, no preempt probe
    # pool headroom growth alone invalidates the cache: the next admit()
    # re-checks for real (still blocked on the slot) and re-caches
    pool.free(r0.block_ids)
    r0.block_ids = []
    assert s.admit() == []
    assert s.head_checks_skipped == base + 5
    assert s.admit() == []
    assert s.head_checks_skipped == base + 6
    # a slot opening is a capacity event: the head admits immediately
    r0.state = RequestState.DONE
    s.release(slot)
    [(_, got)] = s.admit()
    assert got is big and s.queued == 0


def test_preemption_declined_when_gain_cannot_cover_need():
    """A doomed eviction (even all eligible victims' blocks would not fit
    the head) must not happen — completed decode work is never thrown away
    for an admission that still could not proceed.  Mid-PREFILL requests
    are not eligible victims."""
    pool = KVBlockPool(8, block_size=4)
    s = ContinuousScheduler(2, pool=pool)
    for i in range(2):
        s.submit(_req(i, n=9))                  # 14 rows -> 4 blocks each
    pairs = s.admit()
    # only the first low reaches DECODE; the second stays mid-PREFILL
    _, low0 = pairs[0]
    low0.block_ids = pool.alloc_reserved(2)
    low0.blocks_reserved -= 2
    low0.state = RequestState.DECODE
    big = Request(2, np.arange(24, dtype=np.int32), max_new_tokens=9,
                  priority=2)                   # 32 rows -> 8 blocks
    s.submit(big)
    assert s.admit() == []                      # evicting low0 frees only 4
    assert s.preemptions == 0 and low0.state is RequestState.DECODE
