"""Continuous-batching scheduler: lifecycle, slot bookkeeping, admission."""
import numpy as np

from repro.serving.scheduler import (ContinuousScheduler, Request,
                                     RequestState)


def _req(rid, n=4):
    return Request(rid, np.arange(6, dtype=np.int32), max_new_tokens=n)


def test_lifecycle_states():
    s = ContinuousScheduler(2)
    r = _req(0)
    s.submit(r)
    assert r.state is RequestState.QUEUED
    assert s.queued == 1 and s.occupied == 0
    [(slot, admitted)] = s.admit()
    assert admitted is r and r.state is RequestState.PREFILL
    assert s.queued == 0 and s.occupied == 1 and s.load == 1
    r.state = RequestState.DONE
    assert s.release(slot) is r
    assert s.occupied == 0 and not s.has_work()


def test_admit_fills_free_slots_fifo():
    s = ContinuousScheduler(2)
    for i in range(5):
        s.submit(_req(i))
    first = s.admit()
    assert [r.rid for _, r in first] == [0, 1]
    assert s.admit() == []                      # slots full
    assert s.queued == 3
    # the moment a slot frees, the next queued request takes exactly it
    slot = first[0][0]
    s.release(slot)
    [(slot2, nxt)] = s.admit()
    assert slot2 == slot and nxt.rid == 2


def test_active_and_load_reflect_slots_and_queue():
    s = ContinuousScheduler(3)
    for i in range(4):
        s.submit(_req(i))
    s.admit()
    assert {r.rid for _, r in s.active()} == {0, 1, 2}
    assert s.load == 4 and s.queued == 1
    assert s.has_work()


def test_wait_for_work_signals_on_submit():
    s = ContinuousScheduler(1)
    assert not s.wait_for_work(timeout=0.01)
    s.submit(_req(0))
    assert s.wait_for_work(timeout=0.01)


def test_request_metrics_and_clone():
    r = _req(7)
    r.submitted_at = 10.0
    r.first_token_at = 10.5
    r.finished_at = 11.5
    r.output = [1, 2, 3]
    assert r.ttft_s == 0.5
    assert abs(r.tpot_s - 0.5) < 1e-9
    c = r.clone()
    assert c.rid == 7 and c.output == [] and c.first_token_at is None
    assert c.submitted_at == 10.0               # TTFT measured from arrival
