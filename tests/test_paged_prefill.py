"""Cache-seeded chunked prefill: paged prefill-attention kernel vs oracle,
model-level chunked-vs-dense equivalence, engine-level seeded-vs-recompute
greedy equality (incl. int8 pools), block/bucket boundary prompt lengths,
preemption-resume with zero recomputed prefix tokens, prefill/decode
interleaving, and the prefix-index trim order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.kernels.prefill_attention.kernel import \
    paged_prefill_attention as pallas_prefill
from repro.kernels.prefill_attention.ref import paged_prefill_attention_ref
from repro.models import transformer as T
from repro.models.layers.attention import chunked_attention
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import greedy


def _smoke():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _direct_greedy(cfg, params, prompt, n_new, max_len):
    fns = fns_for(cfg)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    lg, st = fns.prefill(cfg, params, batch, max_len=max_len)
    out = []
    for _ in range(n_new):
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        lg, st = fns.decode(cfg, params, jnp.asarray([[tok]], jnp.int32), st)
    return out


# -- kernel vs oracle ----------------------------------------------------------

def _chunk_case(seed, B=2, C=8, mb=5, bs=8, K=2, H=4, D=16):
    """Random pool + disjoint tables + per-sequence chunk offsets."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    N = 1 + B * mb
    q = jax.random.normal(ks[0], (B, C, H, D))
    k_pool = jax.random.normal(ks[1], (N, bs, K, D))
    v_pool = jax.random.normal(ks[2], (N, bs, K, D))
    rng = np.random.default_rng(seed)
    tables = 1 + rng.permutation(B * mb).reshape(B, mb).astype(np.int32)
    # chunk origin anywhere a block-aligned chunk fits (seeded rows before)
    q_start = rng.integers(0, mb * bs - C + 1, size=B) // bs * bs
    lengths = q_start + C
    return (q, k_pool, v_pool, jnp.asarray(tables),
            jnp.asarray(q_start.astype(np.int32)),
            jnp.asarray(lengths.astype(np.int32)))


@pytest.mark.parametrize("seed", range(3))
def test_prefill_ref_matches_dense_causal(seed):
    """The paged oracle equals dense causal attention over the gathered
    cache with query positions offset to the chunk origin."""
    q, kp, vp, tables, q_start, lengths = _chunk_case(seed)
    B, C = q.shape[:2]
    mb, bs = tables.shape[1], kp.shape[1]
    kd = kp[tables].reshape(B, mb * bs, *kp.shape[2:])
    vd = vp[tables].reshape(B, mb * bs, *vp.shape[2:])
    qpos = q_start[:, None] + jnp.arange(C)[None]
    dense = chunked_attention(q, kd, vd, causal=True, q_positions=qpos,
                              kv_positions=jnp.arange(mb * bs),
                              kv_len=lengths)
    out = paged_prefill_attention_ref(q, kp, vp, tables, q_start, lengths)
    np.testing.assert_allclose(out, dense, atol=1e-6)


@pytest.mark.parametrize("seed", range(2))
def test_prefill_pallas_matches_ref(seed):
    q, kp, vp, tables, q_start, lengths = _chunk_case(seed)
    out = pallas_prefill(q, kp, vp, tables, q_start, lengths, interpret=True)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, q_start, lengths)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_prefill_pallas_int8_matches_ref():
    q, kp, vp, tables, q_start, lengths = _chunk_case(5)
    kq, ks = T.quantize_kv(kp)
    vq, vs = T.quantize_kv(vp)
    out = pallas_prefill(q, kq, vq, tables, q_start, lengths,
                         k_scale=ks, v_scale=vs, interpret=True)
    ref = paged_prefill_attention_ref(q, kq, vq, tables, q_start, lengths,
                                      k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_prefill_trash_and_future_blocks_never_attended():
    """Garbage in the trash block and in table entries past the valid
    length must not leak into the chunk's outputs."""
    q, kp, vp, tables, q_start, lengths = _chunk_case(7)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, q_start, lengths)
    poisoned_k = kp.at[0].set(1e4)
    poisoned_v = vp.at[0].set(-1e4)
    out = paged_prefill_attention_ref(q, poisoned_k, poisoned_v, tables,
                                      q_start, lengths)
    np.testing.assert_allclose(out, ref, atol=1e-6)


# -- model level: chunked paged prefill vs dense prefill ----------------------

def test_prefill_paged_chunked_matches_dense():
    """Writing a prompt into pool blocks chunk by chunk and reading logits
    at the last real token equals the dense full-prompt prefill."""
    cfg, params = _smoke()
    fns = fns_for(cfg)
    bs, mb, P = 8, 4, 20
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (P,), 0,
                                         cfg.vocab_size), np.int32)
    lg_ref, _ = fns.prefill(cfg, params, {"tokens": jnp.asarray(toks)[None]},
                            max_len=P)
    cache = T.make_paged_cache(cfg, 1 + 8, bs, 1, mb, "bfloat16")
    block_ids = [1, 2, 3]
    tbl = np.zeros((1, mb), np.int32)
    tbl[0, :3] = block_ids
    pos, last = 0, None
    for real, cpad in ((8, 8), (12, 16)):    # final chunk bucket-padded
        ct = np.zeros((1, cpad), np.int32)
        ct[0, :real] = toks[pos:pos + real]
        wids = np.zeros((cpad // bs,), np.int32)
        for j in range(cpad // bs):
            lb = pos // bs + j
            if lb < 3:
                wids[j] = block_ids[lb]
        last, cache = fns.prefill_paged(
            cfg, params, jnp.asarray(ct), cache, jnp.asarray(wids),
            jnp.asarray(tbl), q_start=jnp.asarray([pos], jnp.int32),
            kv_len=jnp.asarray([pos + real], jnp.int32),
            last_idx=jnp.int32(real - 1))
        pos += real
    np.testing.assert_allclose(np.asarray(last), np.asarray(lg_ref),
                               atol=1e-5)


# -- engine: seeded prefill vs full recompute ---------------------------------

def _prefix_workload(cfg, n=4, prefix_tokens=32, block=8, seed=11):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size,
                          size=prefix_tokens).astype(np.int32)
    return [Request(i, np.concatenate(
                    [prefix, rng.integers(0, cfg.vocab_size, size=5)
                     .astype(np.int32)]),
                    max_new_tokens=4, sampler=greedy())
            for i in range(n)]


@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8"])
def test_seeded_prefill_matches_recompute_exactly(cache_dtype):
    """The acceptance invariant: a seeded prefill (shared prefix read from
    the pool, never re-run) must produce greedy continuations identical
    token for token to the full-recompute baseline — including int8
    pools, where both paths read the same quantized prefix rows."""
    cfg, params = _smoke()
    kw = dict(max_len=48, batch_slots=4, paged=True, block_size=8,
              cache_dtype=cache_dtype)
    seeded = ServingEngine(cfg, params, **kw)
    recomp = ServingEngine(cfg, params, seeded_prefill=False, **kw)
    rs = _prefix_workload(cfg)
    rr = _prefix_workload(cfg)
    ss = seeded.serve(rs)
    sr = recomp.serve(rr)
    assert [r.output for r in rs] == [r.output for r in rr]
    # the recompute baseline runs every prompt token; the seeded engine
    # skips the shared prefix (3 of 4 requests seed 4 prefix blocks)
    assert sr.prefill_tokens_computed == sr.prefill_tokens_total
    assert ss.prefill_tokens_total == sr.prefill_tokens_total
    saved = 3 * 32                       # 3 sharers x 4 blocks x 8 tokens
    assert ss.prefill_tokens_computed == ss.prefill_tokens_total - saved
    # both engines still map shared blocks (storage dedup is independent)
    assert ss.prefix_shared_blocks == sr.prefix_shared_blocks == 12
    assert seeded.pool.used_blocks == 0
    assert seeded.pool.reserved_blocks == 0


def test_seeded_prefill_matches_contiguous_engine():
    """Seeded paged serving equals the contiguous (dense-prefill) engine's
    greedy outputs — the cross-layout ground truth."""
    cfg, params = _smoke()
    rs = _prefix_workload(cfg)
    rc = _prefix_workload(cfg)
    seeded = ServingEngine(cfg, params, max_len=48, batch_slots=4,
                           paged=True, block_size=8)
    contig = ServingEngine(cfg, params, max_len=48, batch_slots=4,
                           paged=False)
    seeded.serve(rs)
    contig.serve(rc)
    assert [r.output for r in rs] == [r.output for r in rc]


@pytest.mark.parametrize("P", [7, 8, 9, 15, 16, 17])
def test_boundary_prompt_lengths_seed_and_match(P):
    """Prompt lengths exactly at (and around) block and bucket boundaries:
    two identical co-resident prompts — the second seeds every *sharable*
    block (capped one token short of the prompt, since the last token's
    logits must be computed) — and both match the contiguous engine."""
    cfg, params = _smoke()
    bs = 8
    prompt = (np.arange(P, dtype=np.int32) * 7 + 3) % cfg.vocab_size
    mk = lambda: [Request(i, prompt.copy().astype(np.int32),  # noqa: E731
                          max_new_tokens=3, sampler=greedy())
                  for i in range(2)]
    paged = ServingEngine(cfg, params, max_len=P + 4, batch_slots=2,
                          paged=True, block_size=bs)
    contig = ServingEngine(cfg, params, max_len=P + 4, batch_slots=2,
                           paged=False)
    rp, rc = mk(), mk()
    sp = paged.serve(rp)
    contig.serve(rc)
    assert [r.output for r in rp] == [r.output for r in rc]
    seeded_tokens = ((P - 1) // bs) * bs      # full blocks short of the end
    assert sp.prefill_tokens_total == 2 * P
    assert sp.prefill_tokens_computed == 2 * P - seeded_tokens
    assert paged.pool.used_blocks == 0 and paged.pool.reserved_blocks == 0


# -- preemption resume: surviving history is seeded, not recomputed -----------

def test_preemption_resume_recomputes_zero_prefix_tokens():
    """A preempted decode whose prompt prefix survives in the pool (via a
    co-holder) resumes by seeding those blocks: the re-admission computes
    exactly prompt+generated minus the seeded prefix — zero prefix tokens
    re-run — and still finishes with the un-preempted greedy output."""
    cfg, params = _smoke()
    bs = 8
    rng = np.random.default_rng(17)
    prefix = rng.integers(0, cfg.vocab_size, size=2 * bs).astype(np.int32)
    mk_tail = lambda s: rng.integers(0, cfg.vocab_size,  # noqa: E731
                                     size=4).astype(np.int32)
    anchor = Request(0, np.concatenate([prefix, mk_tail(1)]),
                     max_new_tokens=24, sampler=greedy(), priority=1)
    victim = Request(1, np.concatenate([prefix, mk_tail(2)]),
                     max_new_tokens=8, sampler=greedy(), priority=0)
    expect = _direct_greedy(cfg, params, victim.prompt, 8, 32)
    eng = ServingEngine(cfg, params, max_len=44, batch_slots=2, paged=True,
                        block_size=bs, pool_blocks=10)
    admissions = []                      # (rid, prefill_len, seeded_rows)
    orig_mat = eng._materialize_blocks

    def spy(job):
        orig_mat(job)
        admissions.append((job.req.rid, len(job.tokens), job.pos))
    eng._materialize_blocks = spy

    eng.scheduler.submit(anchor)
    eng.scheduler.submit(victim)
    for _ in range(3):                   # both decoding, a few tokens out
        eng._step()
    assert victim.first_token_at is not None
    high = Request(2, np.arange(8, dtype=np.int32), max_new_tokens=2,
                   sampler=greedy(), priority=2)
    eng.scheduler.submit(high)           # no free slot -> preempts victim
    while eng.scheduler.has_work():
        eng._step()
    assert victim.preempted_count >= 1
    assert len(anchor.output) == 24 and len(high.output) == 2
    assert victim.output == expect       # seeded resume is exact
    resume = [a for a in admissions if a[0] == 1][-1]
    _, prefill_len, seeded_rows = resume
    assert prefill_len > len(victim.prompt)       # history folded in
    assert seeded_rows == len(prefix)             # whole prefix seeded...
    # ...so the resume computed zero prefix tokens: only the tail and the
    # generated history went through the prefill
    assert eng.pool.used_blocks == 0 and eng.pool.reserved_blocks == 0


# -- chunked prefill interleaves with decode steps ----------------------------

def test_chunked_prefill_interleaves_decode_steps():
    cfg, params = _smoke()
    rng = np.random.default_rng(23)
    dec = Request(0, rng.integers(0, cfg.vocab_size, size=6)
                  .astype(np.int32), max_new_tokens=24, sampler=greedy())
    big_prompt = rng.integers(0, cfg.vocab_size, size=64).astype(np.int32)
    big = Request(1, big_prompt.copy(), max_new_tokens=3, sampler=greedy())
    eng = ServingEngine(cfg, params, max_len=80, batch_slots=2, paged=True,
                        block_size=8, prefill_chunk=16)
    eng.scheduler.submit(dec)
    for _ in range(4):
        eng._step()
    eng.scheduler.submit(big)
    interleaved = 0
    while eng.scheduler.has_work():
        before = eng.totals.decode_steps
        had_prefill = bool(eng._prefilling)
        eng._step()
        if had_prefill and eng.totals.decode_steps > before:
            interleaved += 1
    # 64 tokens / 16-token chunks = 4 executor steps with a decode between
    assert interleaved >= 3
    assert dec.output == _direct_greedy(cfg, params, dec.prompt, 24, 80)
    assert big.output == _direct_greedy(cfg, params, big_prompt, 3, 80)
    assert eng.pool.used_blocks == 0 and eng.pool.reserved_blocks == 0


def test_chunked_prefill_still_seeds_shared_prefixes():
    """Chunked mode composes with seeding: block materialization is
    deferred to a job's first chunk, and jobs advance oldest-first, so a
    request admitted in the same batch as an identical-prefix
    predecessor still seeds the predecessor's published blocks — and the
    per-step budget is never overspent across jobs."""
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=48, batch_slots=4, paged=True,
                        block_size=8, prefill_chunk=16)
    spent = []
    orig = eng._advance_prefill

    def spy(slot, budget=None):
        real = orig(slot, budget)
        if spent and spent[-1] is not None:
            spent[-1] += real
        return real

    orig_step = eng._step

    def step_spy():
        spent.append(0 if eng._prefilling else None)
        return orig_step()
    eng._advance_prefill = spy
    eng._step = step_spy
    reqs = _prefix_workload(cfg)         # 4 x (32-token prefix + 5 tail)
    stats = eng.serve(reqs)
    rc = _prefix_workload(cfg)
    contig = ServingEngine(cfg, params, max_len=48, batch_slots=4,
                           paged=False)
    contig.serve(rc)
    assert [r.output for r in reqs] == [r.output for r in rc]
    # 3 of 4 requests seeded the full 4-block prefix despite same-step
    # admission (the first computes everything)
    assert stats.prefill_tokens_computed == stats.prefill_tokens_total \
        - 3 * 32
    # the chunked budget held: no executor step computed > prefill_chunk
    assert max((s for s in spent if s is not None), default=0) <= 16


def test_prefill_chunk_validation():
    cfg, params = _smoke()
    with pytest.raises(ValueError, match="multiple of block_size"):
        ServingEngine(cfg, params, paged=True, block_size=16,
                      prefill_chunk=24)
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, paged=False, prefill_chunk=16)


def test_paged_engine_rejects_sliding_window():
    """The paged attention paths are full-causal: a sliding-window arch
    must be refused rather than silently served with the wrong mask."""
    cfg, params = _smoke()
    sw = cfg.replace(sliding_window=4)
    with pytest.raises(ValueError, match="sliding_window"):
        ServingEngine(sw, params, paged=True)
    ServingEngine(sw, params, paged=False)   # contiguous path still fine


# -- prefix-index trim: stale entries first, then oldest live -----------------

def test_prefix_index_trim_drops_stale_before_live():
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=32, batch_slots=2, paged=True,
                        block_size=8, pool_blocks=8)
    pool = eng.pool
    pool.reserve(4)
    live_ids = pool.alloc_reserved(3)
    for i, b in enumerate(live_ids):     # live entries, oldest first
        eng._prefix_index[b"live%d" % i] = (b, pool.generation(b))
    [dead] = pool.alloc_reserved(1)
    gen = pool.generation(dead)
    pool.free([dead])
    eng._prefix_index[b"dead-freed"] = (dead, gen)
    eng._prefix_index[b"dead-stale"] = (live_ids[0],
                                        pool.generation(live_ids[0]) - 1)
    dummy = Request(9, np.zeros(1, np.int32))
    eng._prefix_cap = 3
    eng._register_prefix([], dummy)      # 5 entries > cap -> trim
    # dead entries went first; every live one survived
    assert set(eng._prefix_index) == {b"live0", b"live1", b"live2"}
    eng._prefix_cap = 2
    eng._register_prefix([], dummy)      # still over cap -> oldest live out
    assert set(eng._prefix_index) == {b"live1", b"live2"}
    pool.free(live_ids)
    pool.unreserve(0)
