"""Pallas kernels vs their jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d.kernel import conv2d
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.kernel import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2, jnp.float16: 5e-3}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 128, 384, 128, 128, 64),
    (512, 256, 128, 256, 128, 256),
])
def test_matmul_sweep(dtype, m, k, n, bm, bn, bk):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    out = matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = matmul_ref(x, y)
    scale = float(jnp.abs(ref.astype(jnp.float32)).max())
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32),
                               atol=TOLS[dtype] * scale)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,K,D,bq,bkv,causal", [
    (128, 4, 2, 32, 64, 64, True),
    (256, 8, 8, 64, 128, 256, True),
    (128, 2, 1, 64, 128, 64, False),
])
def test_flash_attention_sweep(dtype, S, H, K, D, bq, bkv, causal):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (2, S, K, D)).astype(dtype)
    v = jax.random.normal(ks[2], (2, S, K, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=TOLS[dtype] * 3)


@pytest.mark.parametrize("S,H,K,D,bkv", [(256, 4, 2, 32, 64),
                                         (512, 8, 8, 64, 256)])
def test_decode_attention_sweep(S, H, K, D, bkv):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (3, H, D))
    k = jax.random.normal(ks[1], (3, S, K, D))
    v = jax.random.normal(ks[2], (3, S, K, D))
    lengths = jnp.array([1, S // 2, S], jnp.int32)
    out = decode_attention(q, k, v, lengths, bkv=bkv, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("S,H,N,P,chunk", [(128, 2, 8, 8, 32),
                                           (256, 4, 16, 8, 128)])
def test_ssm_scan_sweep(S, H, N, P, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (2, S, H, N))
    k = jax.random.normal(ks[1], (2, S, H, N))
    v = jax.random.normal(ks[2], (2, S, H, P))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (2, S, H)))
    lg = 0.3 * jax.random.normal(ks[4], (2, S, H))
    out = ssm_scan(q, k, v, ld, lg, chunk=chunk, interpret=True)
    ref = ssm_scan_ref(q, k, v, ld, lg, chunk=chunk)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(out, ref, atol=1e-5 * max(scale, 1.0))


@pytest.mark.parametrize("H,W,Cin,KH,Cout,stride", [
    (16, 16, 8, 3, 32, 1), (28, 28, 16, 5, 64, 1), (32, 32, 3, 7, 16, 2),
])
def test_conv2d_sweep(H, W, Cin, KH, Cout, stride):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (2, H, W, Cin))
    w = jax.random.normal(ks[1], (KH, KH, Cin, Cout)) * 0.1
    b = jax.random.normal(ks[2], (Cout,)) * 0.1
    out = conv2d(x, w, b, stride=stride, bc=min(Cout, 32), interpret=True)
    ref = conv2d_ref(x, w, b, stride=stride)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_matmul_tiling_independence():
    """Different block shapes must give bit-identical fp32 results."""
    x = jax.random.normal(jax.random.PRNGKey(6), (256, 256))
    y = jax.random.normal(jax.random.PRNGKey(7), (256, 256))
    a = matmul(x, y, bm=128, bn=128, bk=256, interpret=True)
    b = matmul(x, y, bm=256, bn=64, bk=256, interpret=True)
    np.testing.assert_allclose(a, b, atol=0)   # same K-order -> identical
