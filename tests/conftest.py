"""Test config: single-device CPU (the 512-device flag is dry-run-only).

`hypothesis` is optional: property-based test modules importorskip it, and
the profile is only registered when the package is present, so tier-1
collection never hard-fails on a missing test dependency.
"""
import numpy as np
import pytest

try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("repro", max_examples=12, deadline=None)
    settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
