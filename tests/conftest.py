"""Test config: single-device CPU (the 512-device flag is dry-run-only)."""
import numpy as np
import pytest

from hypothesis import settings

settings.register_profile("repro", max_examples=12, deadline=None)
settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
