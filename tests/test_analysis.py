"""Invariant lint pass: each checker flags its violation fixture and
stays silent on the clean fixture; the baseline round-trips; the repo's
own tree is clean under the shipped baseline (the tier-1 gate)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import default_repo_root, repo_config, run_all
from repro.analysis.baseline import (apply_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.config import AnalysisConfig
from repro.analysis.faultok import check_faultok
from repro.analysis.jitpure import check_jit
from repro.analysis.kernelreg import check_kernels
from repro.analysis.locks import check_locks
from repro.analysis.refgen import check_refgen
from repro.analysis.statscov import check_stats


def _tree(root: Path, files: dict[str, str]) -> None:
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))


def _rules(findings):
    return {f.rule for f in findings}


# -- lock discipline -----------------------------------------------------------

def test_locks_flags_unguarded_write(tmp_path):
    _tree(tmp_path, {"pkg/pool.py": """\
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._free = []  # guarded-by: self._lock

            def alloc(self):
                with self._lock:
                    return self._free.pop()

            def leak(self):
                return len(self._free)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, lock_files=["pkg/pool.py"])
    findings = check_locks(cfg)
    assert any(f.rule == "unguarded-field" and f.scope == "Pool.leak"
               for f in findings), findings
    # the guarded access inside `with self._lock` is NOT flagged
    assert not any(f.scope == "Pool.alloc" for f in findings)


def test_locks_assumes_lock_discharges_guard(tmp_path):
    _tree(tmp_path, {"pkg/pool.py": """\
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._refs = {}  # guarded-by: self._lock

            # assumes-lock: self._lock
            def _bump(self, bid):
                self._refs[bid] = self._refs.get(bid, 0) + 1
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, lock_files=["pkg/pool.py"])
    assert check_locks(cfg) == []


def test_locks_detects_lock_order_cycle(tmp_path):
    _tree(tmp_path, {"pkg/ab.py": """\
        import threading

        class A:
            def __init__(self, b):
                self._lock = threading.Lock()
                self.b = b

            def m(self):
                with self._lock:
                    self.b.poke()

            def ping(self):
                with self._lock:
                    pass

        class B:
            def __init__(self, a):
                self._lock = threading.Lock()
                self.a = a

            def poke(self):
                with self._lock:
                    self.a.ping()
        """})
    cfg = AnalysisConfig(
        repo_root=tmp_path, lock_files=["pkg/ab.py"],
        attr_types={("A", "b"): "B", ("B", "a"): "A"})
    findings = check_locks(cfg)
    cycles = [f for f in findings if f.rule == "lock-order-cycle"]
    assert cycles, findings
    assert "A._lock" in cycles[0].scope and "B._lock" in cycles[0].scope


def test_locks_thread_hygiene(tmp_path):
    _tree(tmp_path, {"pkg/w.py": """\
        import threading

        def spawn():
            return threading.Thread(target=print)

        def spawn_named():
            return threading.Thread(target=print, name="w", daemon=True)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, thread_files=["pkg/w.py"],
                         lock_files=["pkg/w.py"])
    findings = [f for f in check_locks(cfg) if f.rule == "thread-hygiene"]
    assert len(findings) == 1, findings


def test_locks_rejects_unknown_annotation_key(tmp_path):
    _tree(tmp_path, {"pkg/p.py": """\
        import threading

        class P:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded_by: self._lock
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, lock_files=["pkg/p.py"])
    assert "bad-annotation" in _rules(check_locks(cfg))


# -- refcount/generation safety ------------------------------------------------

def test_refgen_flags_unproven_free(tmp_path):
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, refgen_files=["pkg/e.py"])
    findings = check_refgen(cfg)
    assert _rules(findings) == {"unproven-free"}
    assert findings[0].scope == "bad_drop@free"


def test_refgen_accepts_guard_evidence_and_annotation(tmp_path):
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def guarded_drop(self, ids):
                live = [b for b in ids if self.pool.block_live(b)]
                self.pool.free(live)

            def annotated_drop(self, ids):
                self.pool.free(ids)  # generation-safe: tables zeroed next
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, refgen_files=["pkg/e.py"])
    assert check_refgen(cfg) == []


# -- stats coverage ------------------------------------------------------------

_STATS_SRC = """\
    from dataclasses import dataclass

    @dataclass
    class ServeStats:
        tokens: int = 0
        {extra_field}
        rate: float = 0.0

    MERGE_RULES = {{"tokens": "sum", "rate": "derived"{extra_rule}}}
    _DERIVED = {{"rate": None}}
    """


def test_stats_flags_missing_merge_rule(tmp_path):
    _tree(tmp_path, {"pkg/s.py": _STATS_SRC.format(
        extra_field="orphan: int = 0", extra_rule="")})
    cfg = AnalysisConfig(repo_root=tmp_path, stats_file="pkg/s.py")
    findings = check_stats(cfg)
    assert [(f.rule, f.scope) for f in findings] == \
        [("unmerged-field", "orphan")]


def test_stats_flags_stale_rule_and_derived_mismatch(tmp_path):
    _tree(tmp_path, {"pkg/s.py": """\
        from dataclasses import dataclass

        @dataclass
        class ServeStats:
            tokens: int = 0
            rate: float = 0.0

        MERGE_RULES = {"tokens": "sum", "rate": "derived", "ghost": "sum"}
        _DERIVED = {}
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, stats_file="pkg/s.py")
    rules = _rules(check_stats(cfg))
    assert rules == {"stale-rule", "derived-mismatch"}


def test_stats_flags_unknown_counter_mutation(tmp_path):
    _tree(tmp_path, {
        "pkg/s.py": _STATS_SRC.format(extra_field="hits: int = 0",
                                      extra_rule=', "hits": "sum"'),
        "pkg/m.py": """\
            def step(self):
                self.totals.hits += 1
                self.totals.hitz += 1
            """})
    cfg = AnalysisConfig(repo_root=tmp_path, stats_file="pkg/s.py",
                         stats_mutation_files=["pkg/m.py"])
    findings = check_stats(cfg)
    assert [(f.rule, f.scope) for f in findings] == \
        [("unknown-counter", "totals.hitz")]


# -- jit purity ----------------------------------------------------------------

def test_jit_flags_tracer_branch_and_item(tmp_path):
    _tree(tmp_path, {"pkg/j.py": """\
        import jax.numpy as jnp

        def probe(x):
            if jnp.any(jnp.isnan(x)):
                return x.item()
            return 0

        # jit-ok: host-side smoke helper
        def host_probe(x):
            return bool(jnp.any(x))
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, jit_files=["pkg/j.py"])
    findings = check_jit(cfg)
    assert _rules(findings) == {"tracer-branch", "tracer-item"}
    assert all("probe" not in f.scope or "host" not in f.scope
               for f in findings)


def test_jit_flags_unbucketed_shape_key(tmp_path):
    _tree(tmp_path, {"pkg/eng.py": """\
        class Eng:
            def raw(self, prompt):
                self._prefill_shapes.add((1, len(prompt)))

            def bucketed(self, prompt):
                n = self._bucket_len(len(prompt))
                self._prefill_shapes.add((1, n))
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, jit_files=["pkg/eng.py"],
                         shape_cache_file="pkg/eng.py")
    findings = check_jit(cfg)
    assert [(f.rule, f.scope) for f in findings] == \
        [("unbucketed-shape", "raw@shape-cache")]


# -- fault routing -------------------------------------------------------------

def test_faultok_flags_silent_swallow(tmp_path):
    _tree(tmp_path, {"pkg/f.py": """\
        def drain(items):
            for it in items:
                try:
                    it.run()
                except Exception:
                    pass

        def logged(items):
            for it in items:
                try:
                    it.run()
                except Exception as e:
                    print("oops", e)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, fault_files=["pkg/f.py"])
    findings = check_faultok(cfg)
    assert _rules(findings) == {"silent-swallow"}
    assert {f.scope.split("@")[0] for f in findings} == {"drain", "logged"}


def test_faultok_annotation_and_routed_handler_pass(tmp_path):
    _tree(tmp_path, {"pkg/f.py": """\
        def drain(items, errors):
            for it in items:
                try:
                    it.run()
                except Exception as e:  # fault-ok: best-effort teardown
                    pass
                try:
                    it.close()
                except Exception as e:
                    errors.append(e)

        def narrow(it):
            try:
                it.run()
            except KeyError:
                pass
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, fault_files=["pkg/f.py"])
    assert check_faultok(cfg) == []


# -- kernel registry -----------------------------------------------------------

def test_kernels_cross_check(tmp_path):
    _tree(tmp_path, {
        "k/dispatch.py": "def register_kernel(*a, **kw): pass\n",
        "k/good/ops.py": """\
            from repro.kernels.dispatch import register_kernel
            register_kernel("good_op", None)
            register_kernel("orphan_op", None)
            """,
        "k/rogue/ops.py": 'def register_kernel(*a): pass\n'
                          'register_kernel("rogue_op")\n',
        "bench.py": 'COVERAGE = {"good_op": None, "ghost_op": None}\n'})
    (tmp_path / "k/empty").mkdir()
    cfg = AnalysisConfig(repo_root=tmp_path, kernels_dir="k",
                         kernel_bench="bench.py")
    findings = check_kernels(cfg)
    got = {(f.rule, f.scope) for f in findings}
    assert ("no-ops-module", "empty") in got
    assert ("no-dispatch-import", "rogue") in got
    assert ("uncovered-kernel", "orphan_op") in got
    assert ("uncovered-kernel", "rogue_op") in got
    assert ("stale-coverage", "ghost_op") in got


# -- clean fixture + baseline --------------------------------------------------

def test_clean_fixture_is_silent(tmp_path):
    _tree(tmp_path, {
        "pkg/pool.py": """\
            import threading

            class Pool:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._free = []  # guarded-by: self._lock

                def alloc(self):
                    with self._lock:
                        return self._free.pop()
            """,
        "pkg/s.py": _STATS_SRC.format(extra_field="", extra_rule="")})
    cfg = AnalysisConfig(repo_root=tmp_path, lock_files=["pkg/pool.py"],
                         refgen_files=["pkg/pool.py"],
                         jit_files=["pkg/pool.py"],
                         thread_files=["pkg/pool.py"],
                         stats_file="pkg/s.py",
                         stats_mutation_files=["pkg/pool.py"])
    assert run_all(cfg) == []


def test_baseline_roundtrip_and_staleness(tmp_path):
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, refgen_files=["pkg/e.py"])
    findings = check_refgen(cfg)
    write_baseline(tmp_path, findings, "fixture debt for the roundtrip")
    baseline = load_baseline(tmp_path)
    stale = apply_baseline(findings, baseline)
    assert all(f.suppressed for f in findings) and stale == []
    assert all("fixture debt" in note for note in baseline.values())
    # fix the violation: the entry is now stale, and the gate reports it
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)  # generation-safe: fixed
        """})
    findings = check_refgen(cfg)
    stale = apply_baseline(findings, baseline)
    assert findings == [] and len(stale) == 1


def test_baseline_requires_note_and_keeps_old_justifications(tmp_path):
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)
        """})
    cfg = AnalysisConfig(repo_root=tmp_path, refgen_files=["pkg/e.py"])
    findings = check_refgen(cfg)
    with pytest.raises(ValueError, match="triage note"):
        write_baseline(tmp_path, findings, "   ")
    write_baseline(tmp_path, findings, "first triage")
    # a later rewrite with a different note must not clobber the
    # original justification on entries that already existed
    write_baseline(tmp_path, findings, "second triage")
    baseline = load_baseline(tmp_path)
    assert list(baseline.values()) == ["triaged: first triage"]


def test_cli_update_baseline_requires_note(tmp_path):
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main(["--repo-root", str(tmp_path), "--update-baseline"])
    assert main(["--repo-root", str(tmp_path), "--update-baseline",
                 "--note", "clean fixture tree"]) == 0


def test_finding_ids_are_line_independent(tmp_path):
    src = """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)
        """
    _tree(tmp_path, {"pkg/e.py": src})
    cfg = AnalysisConfig(repo_root=tmp_path, refgen_files=["pkg/e.py"])
    fid0 = check_refgen(cfg)[0].fid
    _tree(tmp_path, {"pkg/e.py": "# moved down\n\n" + textwrap.dedent(src)})
    assert check_refgen(cfg)[0].fid == fid0


# -- the repo itself -----------------------------------------------------------

def test_repo_tree_is_clean_under_baseline():
    root = default_repo_root()
    findings = run_all(repo_config(root))
    stale = apply_baseline(findings, load_baseline(root))
    open_findings = [f for f in findings if not f.suppressed]
    assert open_findings == [], "\n".join(f.render() for f in open_findings)
    assert stale == [], f"stale baseline entries: {stale}"


def test_cli_fails_build_on_injected_violation(tmp_path, monkeypatch):
    import repro.analysis.__main__ as cli
    _tree(tmp_path, {"pkg/e.py": """\
        class Engine:
            def bad_drop(self, ids):
                self.pool.free(ids)
        """})
    fixture_cfg = AnalysisConfig(repo_root=tmp_path,
                                 refgen_files=["pkg/e.py"])
    monkeypatch.setattr(cli, "repo_config", lambda root: fixture_cfg)
    assert cli.main(["--repo-root", str(tmp_path)]) == 1


def test_cli_exit_codes(tmp_path):
    root = default_repo_root()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--repo-root", str(root),
         "--json", str(tmp_path / "out.json")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    artifact = json.loads((tmp_path / "out.json").read_text())
    assert "findings" in artifact and artifact["open"] == 0
