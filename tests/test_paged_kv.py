"""Paged KV cache: block pool lifecycle, paged-vs-contiguous attention
equivalence (ragged lengths, int8 pools, Pallas interpret), bucketed
prefill, block-aware admission, and end-to-end engine agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.kernels.decode_attention.kernel import \
    paged_decode_attention as pallas_paged
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.models import transformer as T
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_pool import CapacityError, KVBlockPool
from repro.serving.sampler import greedy


def _smoke():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# -- block pool lifecycle ------------------------------------------------------

def test_pool_alloc_free_cycle():
    pool = KVBlockPool(4, block_size=16)
    assert pool.capacity == 4 and pool.total_blocks == 5
    assert pool.blocks_for(1) == 1 and pool.blocks_for(16) == 1
    assert pool.blocks_for(17) == 2 and pool.blocks_for(0) == 0
    assert pool.reserve(3)
    ids = pool.alloc_reserved(2)
    assert len(ids) == 2 and KVBlockPool.TRASH not in ids
    assert pool.used_blocks == 2 and pool.reserved_blocks == 1
    assert pool.free_blocks == 1                 # 4 - 2 allocated - 1 promised
    assert not pool.reserve(2)                   # transient: defer, no raise
    pool.free(ids)
    pool.unreserve(1)
    assert pool.used_blocks == 0 and pool.free_blocks == 4
    assert pool.peak_used == 2
    pool.reset_peak()
    assert pool.peak_used == 0


def test_pool_double_free_raises():
    pool = KVBlockPool(2)
    pool.reserve(1)
    [b] = pool.alloc_reserved(1)
    pool.free([b])
    with pytest.raises(ValueError, match="double free"):
        pool.free([b])
    with pytest.raises(ValueError, match="double free"):
        pool.free([KVBlockPool.TRASH])           # trash is never allocated


def test_pool_capacity_error_is_typed_and_valueerror():
    pool = KVBlockPool(2, block_size=16)
    with pytest.raises(CapacityError):
        pool.reserve(3)
    assert issubclass(CapacityError, ValueError)


def test_pool_refcount_share_free_and_double_free():
    """Prefix sharing: a shared block survives its first holder's free and
    only returns to the pool when the last holder lets go; double frees
    and shares of unallocated blocks still raise."""
    pool = KVBlockPool(4, block_size=8)
    pool.reserve(2)
    ids = pool.alloc_reserved(2)
    pool.share(ids)                              # second holder
    assert all(pool.refcount(b) == 2 for b in ids)
    assert pool.free(ids) == []                  # first holder: no release
    assert pool.used_blocks == 2 and pool.free_blocks == 2
    released = pool.free(ids)                    # last holder: released
    assert sorted(released) == sorted(ids)
    assert pool.used_blocks == 0 and pool.free_blocks == 4
    with pytest.raises(ValueError, match="double free"):
        pool.free([ids[0]])
    with pytest.raises(ValueError, match="share of unallocated"):
        pool.share([ids[0]])


def test_pool_release_provisional_grow_then_reject_is_invisible():
    """The speculative grow-then-reject cycle leaves every observable pool
    facet — free list, reservation ledger, refcounts, generation tags —
    exactly as it started, so a fully-rejected verify round is a no-op."""
    pool = KVBlockPool(6, block_size=8)
    pool.reserve(2)
    held = pool.alloc_reserved(2)                # a request's committed KV
    pool.reserve(2)                              # the +spec_rows budget
    before = (pool.free_blocks, pool.used_blocks, pool.reserved_blocks,
              [pool.generation(b) for b in range(pool.total_blocks)],
              {b: pool.refcount(b) for b in range(pool.total_blocks)})
    grown = pool.alloc_reserved(2)               # provisional verify rows
    assert pool.used_blocks == 4 and pool.reserved_blocks == 0
    pool.release_provisional(grown)              # verify rejected them all
    after = (pool.free_blocks, pool.used_blocks, pool.reserved_blocks,
             [pool.generation(b) for b in range(pool.total_blocks)],
             {b: pool.refcount(b) for b in range(pool.total_blocks)})
    assert after == before
    # the returned blocks are reserved again: re-growing cannot fail
    assert pool.alloc_reserved(2) and pool.reserved_blocks == 0
    # misuse raises without mutating: free blocks and shared blocks
    pool.share([held[0]])
    with pytest.raises(ValueError, match="shared"):
        pool.release_provisional([held[0]])
    with pytest.raises(ValueError, match="unallocated"):
        pool.release_provisional([KVBlockPool.TRASH])
    assert pool.refcount(held[0]) == 2           # nothing was mutated


def test_pool_generation_invalidates_stale_prefix_entries():
    """A (block, generation) tag goes dead on free and stays dead when the
    block is re-allocated for different contents — the prefix index can
    never alias a reused block."""
    pool = KVBlockPool(1, block_size=8)
    pool.reserve(1)
    [b] = pool.alloc_reserved(1)
    g = pool.generation(b)
    assert pool.block_live(b, g)
    pool.free([b])
    assert not pool.block_live(b, g)             # freed -> dead
    pool.reserve(1)
    [b2] = pool.alloc_reserved(1)
    assert b2 == b                               # same physical block...
    assert not pool.block_live(b, g)             # ...but the old tag stays dead
    assert pool.block_live(b2, pool.generation(b2))


def test_property_generation_tags_across_spill_free_realloc_cycles():
    """Property: through any interleaving of alloc / free / spill (hold +
    idle + demote-under-pressure) / realloc, a (block, generation) tag
    recorded at allocation reads live iff that exact allocation still owns
    the block — the guard that makes an async host-tier fetch safe to
    commit after the spill->free->realloc race."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    @given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "spill"]),
                              st.integers(0, 7)),
                    min_size=1, max_size=40))
    def check(ops):
        pool = KVBlockPool(4, block_size=8, host_blocks=8)
        demoted: list[int] = []
        pool.on_demote = demoted.extend
        tags: list[tuple[int, int]] = []     # (bid, gen) at alloc time
        alive: list[bool] = []               # shadow truth per tag
        owner: dict[int, int] = {}           # request-owned bid -> tag idx
        idle: dict[int, int] = {}            # demotable bid -> tag idx
        for op, pick in ops:
            if op == "alloc":
                if not pool.reserve(1):      # full even after demotions
                    continue
                for b in demoted:            # demote = spill + free: the
                    alive[idle.pop(b)] = False   # fetch guard must die
                demoted.clear()
                [b] = pool.alloc_reserved(1)
                owner[b] = len(tags)
                tags.append((b, pool.generation(b)))
                alive.append(True)
            elif op == "free" and owner:
                b = sorted(owner)[pick % len(owner)]
                pool.free([b])
                alive[owner.pop(b)] = False
            elif op == "spill" and owner:
                b = sorted(owner)[pick % len(owner)]
                pool.hold(b)                 # published to the prefix index
                pool.free([b])               # ...then its request lets go:
                idle[b] = owner.pop(b)       # demotable, still seedable
            for i, (b, g) in enumerate(tags):
                assert pool.block_live(b, g) == alive[i]
        assert pool.demotable_count == len(idle)
        assert pool.used_blocks == len(owner) + len(idle)

    check()


# -- paged attention vs dense oracle ------------------------------------------

def _ragged_case(seed, B=3, mb=4, bs=8, K=2, H=4, D=16):
    """Random pool + disjoint tables + ragged lengths, and the dense
    contiguous gather the paged read must match."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    N = 1 + B * mb
    q = jax.random.normal(ks[0], (B, H, D))
    k_pool = jax.random.normal(ks[1], (N, bs, K, D))
    v_pool = jax.random.normal(ks[2], (N, bs, K, D))
    rng = np.random.default_rng(seed)
    tables = 1 + rng.permutation(B * mb).reshape(B, mb).astype(np.int32)
    lengths = rng.integers(1, mb * bs + 1, size=B).astype(np.int32)
    return q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lengths)


@pytest.mark.parametrize("seed", range(4))
def test_paged_ref_matches_dense_ref_ragged(seed):
    q, kp, vp, tables, lengths = _ragged_case(seed)
    B, mb, bs = q.shape[0], tables.shape[1], kp.shape[1]
    kd = kp[tables].reshape(B, mb * bs, *kp.shape[2:])
    vd = vp[tables].reshape(B, mb * bs, *vp.shape[2:])
    out = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    ref = decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_allclose(out, ref, atol=1e-6)


@pytest.mark.parametrize("seed", range(2))
def test_paged_pallas_matches_ref_ragged(seed):
    q, kp, vp, tables, lengths = _ragged_case(seed)
    out = pallas_paged(q, kp, vp, tables, lengths, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_paged_pallas_int8_matches_ref():
    q, kp, vp, tables, lengths = _ragged_case(7)
    kq, ks = T.quantize_kv(kp)
    vq, vs = T.quantize_kv(vp)
    out = pallas_paged(q, kq, vq, tables, lengths, k_scale=ks, v_scale=vs,
                       interpret=True)
    ref = paged_decode_attention_ref(q, kq, vq, tables, lengths,
                                     k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(out, ref, atol=2e-5)
    # and the quantized path stays close to the fp path (absmax int8)
    fp = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    assert float(jnp.abs(ref - fp).max()) < 0.05


def test_property_paged_matches_dense_over_ragged_lengths():
    """Property: for any block size / table width / ragged lengths / cache
    dtype, paged attention equals the dense gather (hypothesis-driven;
    module stays collectable without hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    @given(st.integers(0, 10**6), st.sampled_from([4, 8, 16]),
           st.integers(1, 4), st.booleans())
    def prop(seed, bs, mb, quant):
        rng = np.random.default_rng(seed)
        B, K, H, D = 2, 2, 4, 8
        N = 1 + B * mb
        ks = jax.random.split(jax.random.PRNGKey(seed % (2**31)), 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (N, bs, K, D))
        vp = jax.random.normal(ks[2], (N, bs, K, D))
        tables = jnp.asarray(
            1 + rng.permutation(B * mb).reshape(B, mb).astype(np.int32))
        lengths = jnp.asarray(
            rng.integers(1, mb * bs + 1, size=B).astype(np.int32))
        scales = {}
        if quant:
            kp, ksc = T.quantize_kv(kp)
            vp, vsc = T.quantize_kv(vp)
            scales = dict(k_scale=ksc, v_scale=vsc)
        out = paged_decode_attention_ref(q, kp, vp, tables, lengths,
                                         **scales)
        kd = kp[tables].reshape(B, mb * bs, K, D)
        vd = vp[tables].reshape(B, mb * bs, K, D)
        if quant:
            kd = (kd.astype(jnp.float32)
                  * scales["k_scale"][tables].reshape(B, mb * bs, K)[
                      ..., None]).astype(q.dtype)
            vd = (vd.astype(jnp.float32)
                  * scales["v_scale"][tables].reshape(B, mb * bs, K)[
                      ..., None]).astype(q.dtype)
        ref = decode_attention_ref(q, kd, vd, lengths)
        np.testing.assert_allclose(out, ref, atol=2e-6)

    prop()


def test_paged_trash_block_rows_never_attended():
    """Garbage in dead table entries / the trash block must not leak into
    the output of live rows."""
    q, kp, vp, tables, lengths = _ragged_case(3)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    poisoned_k = kp.at[0].set(1e4)          # trash block full of garbage
    poisoned_v = vp.at[0].set(-1e4)
    out = paged_decode_attention_ref(q, poisoned_k, poisoned_v, tables,
                                     lengths)
    np.testing.assert_allclose(out, ref, atol=1e-6)


# -- paged decode_step vs dense decode_step (model level, incl. int8) ---------

def _paged_state_from_prefill(cfg, st: T.KVCache, bs, mb, dtype):
    """Scatter a dense batch-B prefill cache into a paged cache with
    ``mb``-wide block tables (each sequence gets its own contiguous run of
    blocks; entries past the prefill hold spare blocks for decode)."""
    L, B, S, K, D = st.k.shape
    assert S % bs == 0
    nb = S // bs
    assert mb >= nb
    cache = T.make_paged_cache(cfg, 1 + B * mb, bs, B, mb, dtype)
    tables = np.zeros((B, mb), np.int32)
    nxt = 1
    for b in range(B):
        ids = np.arange(nxt, nxt + mb, dtype=np.int32)
        nxt += mb
        tables[b] = ids
        one = jax.tree_util.tree_map(lambda c: c[:, b:b + 1]
                                     if c.ndim > 1 else c, st)
        cache = T.scatter_prefill_blocks(cache, one, jnp.asarray(ids[:nb]))
    return cache._replace(block_tables=jnp.asarray(tables),
                          length=st.length)


@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8"])
def test_paged_decode_step_matches_dense(cache_dtype):
    cfg, params = _smoke()
    fns = fns_for(cfg)
    B, S, extra, bs = 2, 16, 3, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                              cfg.vocab_size)
    _, st = fns.prefill(cfg, params, {"tokens": toks[:, :S]},
                        max_len=S + extra)
    # dense reference cache in the target dtype
    if cache_dtype == "int8":
        kq, ks = T.quantize_kv(st.k)
        vq, vs = T.quantize_kv(st.v)
        dense = T.QuantKVCache(k=kq, v=vq, k_scale=ks, v_scale=vs,
                               length=st.length)
    else:
        dense = st
    # paged cache scatters the S prefill rows; the grown tail rows of the
    # dense cache are zeros, so slicing them off loses nothing
    st_s = T.KVCache(k=st.k[:, :, :S], v=st.v[:, :, :S], length=st.length)
    paged = _paged_state_from_prefill(cfg, st_s, bs, S // bs + 1,
                                      cache_dtype)
    for t in range(S, S + extra):
        lg_d, dense = fns.decode(cfg, params, toks[:, t:t + 1], dense)
        lg_p, paged = fns.decode(cfg, params, toks[:, t:t + 1], paged)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                                   atol=1e-4)
    assert int(paged.length[0]) == S + extra


# -- bucketed prefill ----------------------------------------------------------

def test_bucketed_prefill_logits_match_exact():
    """Right-padding the prompt to a bucket and reading logits at
    last_pos must equal the unpadded prefill (causality)."""
    cfg, params = _smoke()
    fns = fns_for(cfg)
    P, bucket = 9, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, P), 0,
                              cfg.vocab_size)
    lg_ref, _ = fns.prefill(cfg, params, {"tokens": toks})
    padded = jnp.zeros((1, bucket), jnp.int32).at[:, :P].set(toks)
    lg_b, st = fns.prefill(cfg, params,
                           {"tokens": padded,
                            "last_pos": jnp.asarray([P - 1])})
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_ref),
                               atol=1e-5)
    assert st.k.shape[2] == bucket            # cache sized to the bucket


# -- engine: equivalence, leak-freedom, capacity, admission -------------------

def test_paged_engine_matches_contiguous_and_frees_blocks():
    cfg, params = _smoke()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 5, 13, 7, 11)]
    mk = lambda: [Request(i, p, max_new_tokens=3 + (i % 3),  # noqa: E731
                          sampler=greedy())
                  for i, p in enumerate(prompts)]
    paged = ServingEngine(cfg, params, max_len=24, batch_slots=2, paged=True)
    contig = ServingEngine(cfg, params, max_len=24, batch_slots=2,
                           paged=False)
    rp, rc = mk(), mk()
    sp = paged.serve(rp)
    contig.serve(rc)
    assert [r.output for r in rp] == [r.output for r in rc]
    # no leak: every block and reservation returned after serve()
    assert paged.pool.used_blocks == 0
    assert paged.pool.reserved_blocks == 0
    assert sp.kv_blocks_peak >= 1
    assert 0.0 < sp.kv_pool_util <= 1.0
    # bucketing: 5 distinct prompt lengths but only one 16-bucket compile
    assert sp.prefill_compiles == 1


def test_paged_engine_small_pool_still_serves_all():
    """A pool sized well below slots x max_len defers admission instead of
    failing, and every request still completes."""
    cfg, params = _smoke()
    rng = np.random.default_rng(6)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=6)
                    .astype(np.int32),
                    max_new_tokens=2 if i % 2 else 10, sampler=greedy())
            for i in range(6)]
    # worst case would be 4 slots x blocks_for(24) = 8 blocks; give it 2
    eng = ServingEngine(cfg, params, max_len=24, batch_slots=4, paged=True,
                        block_size=8, pool_blocks=2)
    stats = eng.serve(reqs)
    assert [len(r.output) for r in reqs] == [10, 2, 10, 2, 10, 2]
    assert stats.kv_blocks_peak <= 2
    assert eng.pool.used_blocks == 0 and eng.pool.reserved_blocks == 0


def test_capacity_error_paths():
    cfg, params = _smoke()
    eng = ServingEngine(cfg, params, max_len=32, batch_slots=2, paged=True,
                        block_size=8, pool_blocks=2)   # 16 KV rows total
    too_big = Request(0, np.arange(8, dtype=np.int32), max_new_tokens=12)
    with pytest.raises(CapacityError, match="KV"):
        eng.serve([too_big])                 # pool capacity, not max_len
    with pytest.raises(CapacityError):
        eng.submit(too_big)
    # the scheduler's own admission guard raises the same typed error
    with pytest.raises(CapacityError):
        eng.scheduler.submit(too_big)
    # a fitting request still serves
    ok = Request(1, np.arange(8, dtype=np.int32), max_new_tokens=6)
    assert eng.serve([ok]).tokens == 6


def test_prefix_sharing_dedups_blocks_and_matches_unshared():
    """Requests with a common full-block prompt prefix map their leading
    table entries to one refcounted copy: same outputs, strictly fewer
    peak pool blocks, balanced pool afterwards."""
    cfg, params = _smoke()
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab_size,
                                                    size=4).astype(np.int32)])
               for _ in range(3)]                # 20 tokens: 2 full blocks
    mk = lambda: [Request(i, p, max_new_tokens=3, sampler=greedy())  # noqa
                  for i, p in enumerate(prompts)]
    kw = dict(max_len=24, batch_slots=3, paged=True, block_size=8)
    shared = ServingEngine(cfg, params, **kw)
    plain = ServingEngine(cfg, params, prefix_sharing=False, **kw)
    rs, rp = mk(), mk()
    ss = shared.serve(rs)
    sp = plain.serve(rp)
    assert [r.output for r in rs] == [r.output for r in rp]
    # 2 shared prefix blocks counted once + 1 own tail block each
    assert ss.prefix_shared_blocks == 4          # 2 sharers x 2 blocks
    assert sp.prefix_shared_blocks == 0
    assert ss.kv_blocks_peak < sp.kv_blocks_peak
    assert ss.kv_blocks_peak < 3 * 2             # < N x prefix-blocks
    # refcounted release: nothing leaks once every sharer is done
    assert shared.pool.used_blocks == 0
    assert shared.pool.reserved_blocks == 0
    # pool churn invalidated every index entry (blocks freed); a second
    # round with the same prefix must re-publish over the dead entries and
    # recover full sharing immediately, not one block per admission
    ss2 = shared.serve(mk())
    assert ss2.prefix_shared_blocks == 4         # same as the first round


def test_paged_engine_int8_cache_top1_stable():
    """End-to-end paged serving with the int8 pool: greedy streams match
    the bf16 paged engine up to at most one top-1 flip *event* (paper's
    top-1-stability criterion, cascade-aware: once one token differs, the
    continuations decode different contexts, so only the first divergence
    per request is an int8-noise event).  Since the cache-seeded prefill,
    prompt attention reads the int8 pool too — consistent with the decode
    path, and required for seeded/recompute bit-equality — so the flip
    can now also land on the first token."""
    cfg, params = _smoke()
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
               for _ in range(2)]
    mk = lambda: [Request(i, p, max_new_tokens=4, sampler=greedy())  # noqa
                  for i, p in enumerate(prompts)]
    bf = ServingEngine(cfg, params, max_len=16, batch_slots=2, paged=True)
    q8 = ServingEngine(cfg, params, max_len=16, batch_slots=2, paged=True,
                       cache_dtype="int8")
    rb, rq = mk(), mk()
    bf.serve(rb)
    q8.serve(rq)
    flips = sum(any(a != b for a, b in zip(ra.output, rb_.output))
                for ra, rb_ in zip(rb, rq))
    assert flips <= 1
    assert q8._state.k.dtype == jnp.int8
