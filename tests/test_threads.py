"""Worker-thread hygiene: every worker the stack spawns is a *named
daemon* thread (so hangs are attributable in a dump and a wedged worker
cannot block interpreter exit), and orderly shutdown leaves no worker
behind.  The static half of this policy is enforced by
``repro.analysis`` (locks/thread-hygiene); this is the runtime half."""
import threading

import jax
import numpy as np

from repro.configs import registry as R
from repro.core.offload import OffloadEngine, SimTarget
from repro.models.registry import fns_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import greedy


def _workers(before: set[int]) -> list[threading.Thread]:
    return [t for t in threading.enumerate() if t.ident not in before]


def test_offload_workers_named_daemon_and_reaped():
    before = {t.ident for t in threading.enumerate()}
    with OffloadEngine([SimTarget(f"t{i}", compute_s=0.001)
                        for i in range(2)]) as eng:
        eng.run(list(range(4)))
        spawned = _workers(before)
        assert spawned, "expected live offload workers"
        for t in spawned:
            assert t.daemon, f"offload worker {t.name!r} is non-daemon"
            assert t.name.startswith("offload-"), t.name
    for t in spawned:
        t.join(timeout=5.0)
    assert not [t for t in _workers(before) if t.is_alive()]


def test_engine_executor_named_daemon_and_reaped():
    cfg = R.smoke("qwen2.5-3b")
    params = fns_for(cfg).init(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_len=16, batch_slots=2)
    before = {t.ident for t in threading.enumerate()}
    eng.start()
    try:
        spawned = _workers(before)
        assert [t.name for t in spawned] == ["serving-executor"]
        assert all(t.daemon for t in spawned)
        done = threading.Event()
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        eng.submit(Request(0, prompt, max_new_tokens=2, sampler=greedy()),
                   on_finish=lambda r: done.set())
        assert done.wait(timeout=60.0)
    finally:
        eng.stop()
    leftovers = [t for t in _workers(before) if t.is_alive()]
    assert not leftovers, [t.name for t in leftovers]
    # no worker anywhere in the process may be an unnamed non-daemon:
    # Thread-N names mean an unattributable hang in a thread dump
    for t in threading.enumerate():
        if t is threading.main_thread():
            continue
        assert t.daemon or not t.name.startswith("Thread-"), t.name
